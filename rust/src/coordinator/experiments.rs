//! Experiment drivers — one function per table/figure of the paper
//! (DESIGN.md §4 maps each to its modules). Every driver returns a
//! [`Table`] whose rows mirror what the paper plots, with the paper's
//! reference values carried in notes so reports are self-checking.
//!
//! Workload-backed figures are declarative: a (workload, grid) pair
//! executed through [`Machine::run`] on the bounded sweep pool
//! ([`parallel_map_bounded`] with the [`Scale`]'s `jobs` width) — no
//! driver constructs a `Core` or lays out buffers by hand. The
//! `mem-sweep`/`pipe-sweep` grids additionally route through the sweep
//! service's job queue ([`crate::service::run_grid`]), so running them
//! against a persistent result store turns repeated invocations into
//! cache hits (see [`mem_sweep_stored`]/[`pipe_sweep_stored`]).

use super::report::Table;
use super::sweep::{parallel_map_bounded, MachinePoint, Parallelism};
use crate::baseline::arm_a53;
use crate::baseline::PicoConfig;
use crate::core::{Core, CoreConfig, Trace};
use crate::isa::reg::*;
use crate::machine::{run_on_pico, Machine};
use crate::mem::MemConfig;
use crate::service::{self, GridOptions, Job, JobKind, Outcome, Progress, ResultStore};
use crate::util::stats::fmt_rate;
use crate::workloads::cpubench::{CpuBench, CpuBenchKind};
use crate::workloads::memcpy::Memcpy;
use crate::workloads::sort::Sort;
use crate::workloads::stream::{Kernel, Stream};
use crate::workloads::{Scenario, Variant, WorkloadReport};
use std::sync::Mutex;

/// Experiment scale: `full` reproduces the paper's sizes (256 MiB memcpy,
/// 64 MiB sort inputs); default is scaled for CI-speed runs with the same
/// asymptotic behaviour (all sizes far exceed the 256 KiB LLC). `jobs`
/// is the sweep-pool width (the `--jobs` flag), carried by value so
/// concurrent drivers can hold different widths.
#[derive(Debug, Clone, Copy, Default)]
pub struct Scale {
    pub full: bool,
    pub jobs: Parallelism,
}

impl Scale {
    pub fn memcpy_bytes(&self) -> usize {
        if self.full {
            256 * 1024 * 1024
        } else {
            8 * 1024 * 1024
        }
    }

    pub fn sort_n(&self) -> usize {
        if self.full {
            16 * 1024 * 1024 // 64 MiB of i32
        } else {
            64 * 1024
        }
    }

    pub fn prefix_n(&self) -> usize {
        if self.full {
            16 * 1024 * 1024
        } else {
            1024 * 1024
        }
    }

    /// Copied bytes for the `mem-sweep` memcpy rows.
    pub fn mem_sweep_bytes(&self) -> usize {
        if self.full {
            64 * 1024 * 1024
        } else {
            4 * 1024 * 1024
        }
    }

    /// Elements for the `mem-sweep` stream/prefix rows.
    pub fn mem_sweep_elems(&self) -> usize {
        if self.full {
            4 * 1024 * 1024
        } else {
            256 * 1024
        }
    }

    pub fn stream_sizes(&self) -> Vec<usize> {
        // Elements per array; Fig. 4's x-axis spans sizes around the
        // cache capacities into DRAM-resident territory.
        if self.full {
            vec![4 * 1024, 16 * 1024, 64 * 1024, 256 * 1024, 1024 * 1024, 4 * 1024 * 1024]
        } else {
            vec![4 * 1024, 16 * 1024, 64 * 1024, 256 * 1024, 1024 * 1024]
        }
    }
}

/// Run vector memcpy of `bytes` on a (vlen, llc_block) machine point.
fn memcpy_point(vlen: usize, llc_block_bits: usize, bytes: usize) -> WorkloadReport {
    let machine = Machine::for_vlen(vlen).llc_block(llc_block_bits);
    let mut w = Memcpy::new();
    machine.run(&mut w, &Scenario::new(Variant::Vector, bytes)).expect("memcpy runs")
}

/// Fig. 3 (left): memcpy throughput vs LLC block size, VLEN = 256.
pub fn fig3_left(scale: Scale) -> Table {
    let bytes = scale.memcpy_bytes();
    let blocks = vec![2048usize, 4096, 8192, 16384];
    let results = parallel_map_bounded(blocks, scale.jobs.workers(), |block_bits| {
        (block_bits, memcpy_point(256, block_bits, bytes))
    });

    let mut t = Table::new(
        format!("Fig. 3 (left): memcpy vs LLC block size ({} MiB, VLEN=256)", bytes >> 20),
        &["LLC block (bits)", "GB/s", "B/cycle", "verified"],
    );
    for (block_bits, r) in &results {
        t.row(&[
            block_bits.to_string(),
            format!("{:.2}", r.throughput.bytes_per_second() / 1e9),
            format!("{:.2}", r.throughput.bytes_per_cycle()),
            r.verified_cell(),
        ]);
    }
    t.note("paper: improvement plateaus at ~8192-bit blocks; 16384-bit selected (Table 1)");
    let first = results.first().unwrap().1.throughput.bytes_per_cycle();
    let last = results.last().unwrap().1.throughput.bytes_per_cycle();
    t.note(format!("monotone gain 2048→16384: {:.2}×", last / first));
    t
}

/// Fig. 3 (right): memcpy throughput vs vector register width.
pub fn fig3_right(scale: Scale) -> Table {
    let bytes = scale.memcpy_bytes();
    let vlens = vec![128usize, 256, 512, 1024];
    let results = parallel_map_bounded(vlens, scale.jobs.workers(), |vlen| {
        let fmax = CoreConfig::for_vlen(vlen).fmax_mhz;
        (vlen, fmax, memcpy_point(vlen, 16384, bytes))
    });

    let mut t = Table::new(
        format!("Fig. 3 (right): memcpy vs vector width ({} MiB, LLC block 16384)", bytes >> 20),
        &["VLEN (bits)", "f_max (MHz)", "GB/s", "B/cycle", "verified"],
    );
    for (vlen, fmax, r) in &results {
        t.row(&[
            vlen.to_string(),
            format!("{fmax:.0}"),
            format!("{:.2}", r.throughput.bytes_per_second() / 1e9),
            format!("{:.2}", r.throughput.bytes_per_cycle()),
            r.verified_cell(),
        ]);
    }
    t.note("paper: 0.69 GB/s at VLEN=256 (150 MHz); 1.37 GB/s at VLEN=1024 (125 MHz)");
    t
}

/// Table 1: the selected configuration.
pub fn table1() -> Table {
    let mem = MemConfig::paper_default();
    let core = CoreConfig::paper_default();
    let mut t = Table::new("Table 1: selected configuration", &["component", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("IL1", format!("{} sets, direct-mapped, {}-bit blocks (= {} KiB, registers)",
            mem.il1.sets, mem.il1.block_bits, mem.il1.capacity_bytes() / 1024)),
        ("DL1", format!("{} sets, {} ways, {}-bit blocks (= {} KiB, BRAM)",
            mem.dl1.sets, mem.dl1.ways, mem.dl1.block_bits, mem.dl1.capacity_bytes() / 1024)),
        ("LLC", format!("{} sets, {} ways, {}-bit blocks, {} sub-blocks (= {} KiB, BRAM)",
            mem.llc.sets, mem.llc.ways, mem.llc.block_bits, mem.llc_sub_blocks(),
            mem.llc.capacity_bytes() / 1024)),
        ("VLEN", format!("{} bits ({} lanes)", core.vlen_bits, core.lanes())),
        ("interconnect", format!("{}-bit AXI, double rate: {}, burst setup {} cycles",
            mem.dram.axi_width_bits, mem.dram.double_rate, mem.dram.burst_setup_cycles)),
        ("f_max", format!("{} MHz", core.fmax_mhz)),
        ("replacement", "NRU (1 bit/block) at DL1 and LLC; writeback".to_string()),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t
}

/// Table 2: DMIPS/MHz & CoreMark/MHz vs literature rows.
pub fn table2() -> Table {
    let machine = Machine::paper_default();
    let d = machine
        .run(&mut CpuBench::dhrystone(), &Scenario::new(Variant::Scalar, 300))
        .expect("dhrystone runs");
    let c = machine
        .run(&mut CpuBench::coremark(), &Scenario::new(Variant::Scalar, 100))
        .expect("coremark runs");

    let mut t = Table::new(
        "Table 2: indicative comparison ignoring SIMD",
        &["core", "DMIPS/MHz", "CoreMark/MHz", "f_max (MHz)", "platform"],
    );
    // Literature rows as printed in the paper.
    for (name, dm, cm, f, plat) in [
        ("RVCoreP/radix-4 [18]", "1.25", "1.69", "169", "Xilinx Artix-7"),
        ("RVCoreP/DSP [18]", "1.4", "2.33", "169", "Xilinx Artix-7"),
        ("PicoRV32 [44]", "0.52", "N/A", "N/A", "(simulation)"),
        ("RSD/hdiv [23]", "2.04", "N/A", "95", "Zynq"),
        ("BOOM/hdiv [3,23]", "1.06", "N/A", "76", "Zynq"),
        ("Taiga [12,25]", ">1", "2.53", "~200", "Xilinx Virtex-7"),
    ] {
        t.row(&[name.into(), dm.into(), cm.into(), f.into(), plat.into()]);
    }
    t.row(&[
        "This work (simulated)".into(),
        format!("{:.2}", d.throughput.ipc() * CpuBenchKind::Dhrystone.derive()),
        format!("{:.2}", c.throughput.ipc() * CpuBenchKind::Coremark.derive()),
        "150".into(),
        "cycle-level model".into(),
    ]);
    t.note(format!(
        "measured IPC: dhrystone-like {:.3} (verified: {}), coremark-like {:.3} (verified: {})",
        d.throughput.ipc(),
        d.verified == Some(true),
        c.throughput.ipc(),
        c.verified == Some(true)
    ));
    t.note("paper: 1.47 DMIPS/MHz, 2.26 CoreMark/MHz; scores derived from IPC × published RV32 -O2 instruction counts (see workloads::cpubench)");
    t
}

/// Fig. 4: adapted STREAM, softcore vs PicoRV32, across array sizes.
pub fn fig4(scale: Scale) -> Table {
    let sizes = scale.stream_sizes();
    let mut t = Table::new(
        "Fig. 4: adapted STREAM (no SIMD), MB/s",
        &["array KiB", "Copy", "Scale", "Add", "Triad", "Pico Copy", "Pico Scale", "Pico Add", "Pico Triad"],
    );
    let rows = parallel_map_bounded(sizes, scale.jobs.workers(), |n| {
        // Softcore rows (DRAM auto-sizes to the 3-array footprint).
        let machine = Machine::paper_default();
        let mut soft = Vec::new();
        for k in Kernel::ALL {
            let mut w = Stream::new(k);
            let r = machine.run(&mut w, &Scenario::new(Variant::Scalar, n)).expect("stream runs");
            assert!(r.verified == Some(true), "{} failed", k.name());
            soft.push(r.throughput.bytes_per_second() / 1e6);
        }
        // PicoRV32: sizes above its flat behaviour threshold simulate
        // slowly (every access is a 40-cycle transaction); its rates are
        // size-independent, so measure on a capped size.
        let pico_n = n.min(16 * 1024);
        let mut pico_rates = Vec::new();
        for k in Kernel::ALL {
            let mut w = Stream::new(k);
            let r = run_on_pico(&mut w, PicoConfig::default(), &Scenario::new(Variant::Scalar, pico_n))
                .expect("pico runs");
            pico_rates.push(r.throughput.bytes_per_second() / 1e6);
        }
        (n, soft, pico_rates)
    });
    for (n, soft, pico) in rows {
        let mut cells = vec![format!("{}", n * 4 / 1024)];
        cells.extend(soft.iter().map(|v| format!("{v:.1}")));
        cells.extend(pico.iter().map(|v| format!("{v:.1}")));
        t.row(&cells);
    }
    t.note("paper: softcore Copy 183.4 MB/s; PicoRV32 flat 4.8/3.6/4.4/4.0 MB/s across sizes");
    t
}

/// §4.1/§4.2 ratios: 38× (STREAM Copy) and 144× (256-bit memcpy) over
/// PicoRV32.
pub fn fig4_ratios(scale: Scale) -> Table {
    let machine = Machine::paper_default();
    // Softcore STREAM copy at a DRAM-resident size.
    let n = 1024 * 1024;
    let soft = machine
        .run(&mut Stream::new(Kernel::Copy), &Scenario::new(Variant::Scalar, n))
        .expect("stream");
    let soft_mbps = soft.throughput.bytes_per_second() / 1e6;
    // Softcore vector memcpy.
    let vec = machine
        .run(
            &mut Memcpy::new(),
            &Scenario::new(Variant::Vector, scale.memcpy_bytes().min(32 * 1024 * 1024)),
        )
        .expect("memcpy");
    // The paper's 144× is 0.69 GB/s (copied bytes) over 4.8 MB/s —
    // plain copied-byte rate, not the STREAM 2× convention.
    let vec_mbps = vec.throughput.bytes_per_second() / 1e6;

    // PicoRV32 copy.
    let pico_n = 16 * 1024;
    let pico = run_on_pico(
        &mut Stream::new(Kernel::Copy),
        PicoConfig::default(),
        &Scenario::new(Variant::Scalar, pico_n),
    )
    .expect("pico");
    let pico_mbps = pico.throughput.bytes_per_second() / 1e6;

    let mut t = Table::new("§4.1–4.2 ratios vs PicoRV32", &["metric", "value"]);
    t.row(&["softcore STREAM Copy".into(), format!("{soft_mbps:.1} MB/s")]);
    t.row(&["softcore 256-bit memcpy".into(), format!("{vec_mbps:.1} MB/s")]);
    t.row(&["PicoRV32 Copy".into(), format!("{pico_mbps:.1} MB/s")]);
    t.row(&["STREAM Copy ratio".into(), format!("{:.0}×", soft_mbps / pico_mbps)]);
    t.row(&["memcpy ratio".into(), format!("{:.0}×", vec_mbps / pico_mbps)]);
    t.note("paper: 38× (Copy) and 144× (256-bit memcpy)");
    t
}

/// Fig. 5: merge-block semantics on the paper's example shape.
pub fn fig5() -> Table {
    use crate::simd::{CustomUnit, MergeUnit, UnitInputs, VecVal};
    let mut unit = MergeUnit::new(8);
    let a = VecVal::from_i32s(&[2, 4, 6, 8, 10, 12, 14, 16]);
    let b = VecVal::from_i32s(&[1, 3, 5, 7, 9, 11, 13, 15]);
    let out = unit
        .execute(&UnitInputs { funct3: 0, rs1: 0, rs2: 0, imm: 0, vrs1: a, vrs2: b })
        .expect("merge");
    let mut t = Table::new("Fig. 5: c1_merge semantics", &["operand", "lanes"]);
    t.row(&["vrs1 (sorted)".into(), a.to_string()]);
    t.row(&["vrs2 (sorted)".into(), b.to_string()]);
    t.row(&["vrd1 (low half)".into(), out.vrd1.unwrap().to_string()]);
    t.row(&["vrd2 (high half)".into(), out.vrd2.unwrap().to_string()]);
    t.note(format!("merge pipeline depth: {} cycles (leading stage + log2(16) layers)", out.latency));
    t
}

/// Fig. 6: cycle-level trace of the sorting-in-chunks loop.
pub fn fig6() -> String {
    let mut a = crate::asm::Asm::new();
    let data: Vec<u32> = (0..64u32).rev().collect();
    let d = a.words("data", &data);
    a.la(A0, d);
    a.li(A2, 0);
    a.li(A3, 256);
    let l = a.here("chunk");
    a.lv(V1, A0, A2);
    a.addi(T0, A2, 32);
    a.lv(V2, A0, T0);
    a.sort8(V1, V1);
    a.sort8(V2, V2);
    a.merge(V1, V2, V1, V2);
    a.sv(V1, A0, A2);
    a.sv(V2, A0, T0);
    a.addi(A2, A2, 64);
    a.bne(A2, A3, l);
    a.halt();
    let prog = a.assemble().expect("fig6 program");

    let mut core = Core::paper_default();
    core.load(&prog).expect("fig6 program fits default DRAM");
    // Trace the second loop iteration (caches warm — the paper's figure
    // shows the steady-state loop).
    core.trace = Trace::windowed(15, 35);
    core.run(10_000).expect("fig6 runs");
    let mut out = String::from(
        "Fig. 6: instruction start/end cycles, sorting-in-chunks loop (steady state)\n",
    );
    out.push_str(&core.trace.render_pipeline());
    out.push_str("\nNote the two c2.sort calls overlapping (pipelining), the second\n\
                  shifted by the second lv's latency, then c1.merge consuming both —\n\
                  the paper's Fig. 6 schedule.\n");
    out
}

/// §4.3.1: sorting speedups (vs softcore qsort and vs ARM A53 qsort).
pub fn sec43_sort(scale: Scale) -> Table {
    let n = scale.sort_n();
    let variants = vec![Variant::Scalar, Variant::Vector];
    let results = parallel_map_bounded(variants, scale.jobs.workers(), |variant| {
        Machine::paper_default()
            .run(&mut Sort::new(), &Scenario::new(variant, n))
            .expect("sort runs")
    });
    let (q, m) = (&results[0], &results[1]);
    let fmax = 150e6;
    let q_secs = q.throughput.cycles as f64 / fmax;
    let m_secs = m.throughput.cycles as f64 / fmax;
    let a53_secs = arm_a53::qsort_seconds(n);

    let mut t = Table::new(
        format!("§4.3.1: sorting {} Ki elements ({} KiB)", n >> 10, (n * 4) >> 10),
        &["implementation", "cycles/elem", "time (s)", "speedup", "verified"],
    );
    t.row(&[
        "qsort() on softcore".into(),
        format!("{:.1}", q.cycles_per_elem()),
        format!("{q_secs:.3}"),
        "1.0× (baseline)".into(),
        q.verified_cell(),
    ]);
    t.row(&[
        "vector mergesort (c2_sort + c1_merge)".into(),
        format!("{:.1}", m.cycles_per_elem()),
        format!("{m_secs:.3}"),
        format!("{:.1}×", q_secs / m_secs),
        m.verified_cell(),
    ]);
    t.row(&[
        "qsort() on ARM A53 @1.2 GHz (calibrated model)".into(),
        "-".into(),
        format!("{a53_secs:.3}"),
        format!("{:.1}× vs A53", a53_secs / m_secs),
        "model".into(),
    ]);
    t.note("paper: 12.1× over softcore qsort, 1.8× over A53 qsort (64 MiB input)");
    t
}

/// §4.3.2: prefix-sum speedups.
pub fn sec43_prefix(scale: Scale) -> Table {
    let n = scale.prefix_n();
    let variants = vec![Variant::Scalar, Variant::Vector];
    let results = parallel_map_bounded(variants, scale.jobs.workers(), |variant| {
        Machine::paper_default()
            .run(&mut crate::workloads::prefix::Prefix::new(), &Scenario::new(variant, n))
            .expect("prefix runs")
    });
    let (s, v) = (&results[0], &results[1]);
    let fmax = 150e6;
    let s_secs = s.throughput.cycles as f64 / fmax;
    let v_secs = v.throughput.cycles as f64 / fmax;
    let a53_secs = arm_a53::prefix_seconds(n);

    let mut t = Table::new(
        format!("§4.3.2: prefix sum over {} Ki elements", n >> 10),
        &["implementation", "cycles/elem", "time (s)", "speedup", "verified"],
    );
    t.row(&[
        "serial on softcore".into(),
        format!("{:.2}", s.cycles_per_elem()),
        format!("{s_secs:.4}"),
        "1.0× (baseline)".into(),
        s.verified_cell(),
    ]);
    t.row(&[
        "c3_prefix vector".into(),
        format!("{:.2}", v.cycles_per_elem()),
        format!("{v_secs:.4}"),
        format!("{:.1}×", s_secs / v_secs),
        v.verified_cell(),
    ]);
    t.row(&[
        "serial on ARM A53 @1.2 GHz (calibrated model)".into(),
        "-".into(),
        format!("{a53_secs:.4}"),
        format!("{:.2}× of A53 speed", a53_secs / v_secs),
        "model".into(),
    ]);
    t.note("paper: 4.1× over serial softcore; 0.4× the speed of the A53 (64 MiB)");
    t
}

/// §6 discussion: instruction/cycle count reduction vs SSE sorting
/// networks.
pub fn discussion() -> Table {
    use crate::simd::networks;
    let sort8_cycles = networks::sort_latency(8);
    let mut t = Table::new(
        "§6: c2_sort vs SSE sorting-network sequence (Chhugani et al. [8])",
        &["metric", "SSE (4 elems)", "c2_sort (8 elems)", "reduction"],
    );
    t.row(&[
        "instructions".into(),
        "13".into(),
        "1".into(),
        "13×".into(),
    ]);
    t.row(&[
        "cycles".into(),
        "26".into(),
        format!("{sort8_cycles}"),
        format!("{:.1}×", 26.0 / sort8_cycles as f64),
    ]);
    t.row(&["problem size".into(), "4".into(), "8".into(), "2× larger".into()]);
    t.note("paper: 13× fewer instructions and 4.3× fewer cycles while solving a 2× bigger problem");
    t
}

/// Run a sweep grid through the service queue against `store`, in
/// input order, panicking on any failed point (these grids are healthy
/// by construction — a failure is a bug, exactly as the old inline
/// `.expect` was). Returns the outcomes plus how many points were
/// served from the store instead of simulated.
fn run_sweep_jobs(
    jobs: Vec<Job>,
    width: Parallelism,
    store: &Mutex<ResultStore>,
) -> (Vec<Outcome>, u64) {
    let hits0 = store.lock().expect("store lock").hits();
    let progress = Progress::new(jobs.len() as u64);
    let opts = GridOptions { parallelism: width, retries: 0, ..Default::default() };
    let recs = service::run_grid(jobs, store, &progress, &opts, &service::default_exec(), |_| {});
    let outcomes = recs
        .into_iter()
        .map(|r| {
            let r = r.expect("sweep grids run to completion");
            match r.outcome {
                Some(o) => o,
                None => panic!("sweep point failed: {} ({:?})", r.job.label(), r.error),
            }
        })
        .collect();
    let hits = store.lock().expect("store lock").hits() - hits0;
    (outcomes, hits)
}

/// The workload (and variant) of a sweep-grid job.
fn sim_fields(job: &Job) -> (&str, Variant) {
    match &job.kind {
        JobKind::Sim { workload, variant, .. } => (workload, *variant),
        _ => unreachable!("sweep grids contain only sim jobs"),
    }
}

fn outcome_verified_cell(o: &Outcome) -> String {
    match o.verified {
        Some(v) => v.to_string(),
        None => "-".into(),
    }
}

/// The streaming-bandwidth curve behind the non-blocking memory
/// hierarchy: stream/memcpy/prefix (vector variants) swept over LLC
/// block width × memory-port configuration (MSHR count, prefetch depth,
/// DRAM channels). The `mshrs=1` rows are the paper's blocking port —
/// every other row's "Δcyc" column reports its cycle-count improvement
/// over the blocking row of the same (workload, block) pair. `--json`
/// output of this table is what CI captures as `BENCH_mem.json`.
pub fn mem_sweep(scale: Scale) -> Table {
    mem_sweep_stored(scale, &Mutex::new(ResultStore::in_memory()))
}

/// [`mem_sweep`] against a caller-owned result store: points already in
/// the store are served from cache instead of simulated (the table's
/// last note reports the hit count), so re-running after a crash — or
/// a second invocation against a persistent store — only simulates
/// what is missing.
pub fn mem_sweep_stored(scale: Scale, store: &Mutex<ResultStore>) -> Table {
    mem_sweep_sized(scale.mem_sweep_bytes(), scale.mem_sweep_elems(), scale.jobs, store)
}

fn mem_sweep_sized(
    memcpy_bytes: usize,
    elems: usize,
    width: Parallelism,
    store: &Mutex<ResultStore>,
) -> Table {
    let workloads = [("memcpy", memcpy_bytes), ("stream-copy", elems), ("prefix", elems)];
    let blocks = [2048usize, 16384];
    // (mshrs, prefetch, channels): blocking baseline, non-blocking with
    // prefetch, and non-blocking with doubled DRAM bandwidth.
    let ports = [(1usize, 0usize, 1usize), (4, 4, 1), (8, 8, 2)];

    let mut jobs = Vec::new();
    for &(workload, size) in &workloads {
        for &llc_block in &blocks {
            for &(mshrs, prefetch, channels) in &ports {
                let mp =
                    MachinePoint { llc_block, mshrs, prefetch, channels, ..Default::default() };
                jobs.push(Job::sim(mp, workload, Variant::Vector, size));
            }
        }
    }
    let (outcomes, hits) = run_sweep_jobs(jobs.clone(), width, store);
    let results: Vec<(&Job, &Outcome)> = jobs.iter().zip(outcomes.iter()).collect();

    let mut t = Table::new(
        format!(
            "mem-sweep: bandwidth vs LLC block x memory port ({} MiB memcpy, {} Ki elems)",
            memcpy_bytes >> 20,
            elems >> 10
        ),
        &["workload", "LLC block", "MSHRs", "prefetch", "channels", "cycles", "B/cycle",
          "GB/s", "LLC pf", "DRAM queue cyc", "struct/bw stall", "verified", "Δcyc vs blocking"],
    );
    for (job, r) in &results {
        let wl = sim_fields(job).0;
        // The blocking counterpart: same workload + block, mshrs = 1.
        let base = results
            .iter()
            .find(|(q, _)| {
                sim_fields(q).0 == wl
                    && q.point.llc_block == job.point.llc_block
                    && q.point.mshrs == 1
            })
            .map(|(_, r)| r.cycles)
            .unwrap_or(r.cycles);
        let delta = if job.point.mshrs == 1 {
            "baseline".to_string()
        } else {
            format!("{:+.1}%", (1.0 - r.cycles as f64 / base as f64) * 100.0)
        };
        t.row(&[
            wl.to_string(),
            job.point.llc_block.to_string(),
            job.point.mshrs.to_string(),
            job.point.prefetch.to_string(),
            job.point.channels.to_string(),
            r.cycles.to_string(),
            format!("{:.2}", r.bytes_per_cycle()),
            format!("{:.3}", r.bytes_per_second() / 1e9),
            r.metric("llc_prefetches").to_string(),
            r.metric("dram_queue_cycles").to_string(),
            format!("{}/{}", r.metric("mem_struct_stall_cycles"), r.metric("mem_bw_stall_cycles")),
            outcome_verified_cell(r),
            delta,
        ]);
    }
    t.note("mshrs=1 rows are the paper's blocking port; Δcyc is the reduction vs that row");
    t.note("narrow (2048-bit) LLC blocks expose the most miss latency — MSHRs + prefetch win there");
    t.note("the paper's 16384-bit blocks already amortise much of the miss cost by design");
    t.note(format!("result store: {hits} cache hits / {} points", results.len()));
    t
}

/// The issue-width curve behind the dual-issue pipeline model:
/// cpubench (dhrystone/coremark), scalar STREAM copy and the vector
/// memcpy/prefix kernels swept over `issue_width ∈ {1, 2, 4}`. The
/// width-1 rows are the paper's single-issue model — every other row's
/// "Δcyc" column reports its cycle-count reduction over the width-1 row
/// of the same workload. `--json` output of this table is what CI
/// captures as `BENCH_pipeline.json`.
pub fn pipe_sweep(scale: Scale) -> Table {
    pipe_sweep_stored(scale, &Mutex::new(ResultStore::in_memory()))
}

/// [`pipe_sweep`] against a caller-owned result store — the same
/// cache/resume semantics as [`mem_sweep_stored`].
pub fn pipe_sweep_stored(scale: Scale, store: &Mutex<ResultStore>) -> Table {
    let m = if scale.full { 8 } else { 1 };
    pipe_sweep_sized(
        300 * m,
        100 * m,
        scale.mem_sweep_elems(),
        scale.mem_sweep_bytes(),
        scale.jobs,
        store,
    )
}

fn pipe_sweep_sized(
    dhrystone_iters: usize,
    coremark_iters: usize,
    elems: usize,
    memcpy_bytes: usize,
    width: Parallelism,
    store: &Mutex<ResultStore>,
) -> Table {
    let rows = [
        ("dhrystone", Variant::Scalar, dhrystone_iters),
        ("coremark", Variant::Scalar, coremark_iters),
        ("stream-copy", Variant::Scalar, elems),
        ("memcpy", Variant::Vector, memcpy_bytes),
        ("prefix", Variant::Vector, elems),
    ];
    let mut jobs = Vec::new();
    for &(workload, variant, size) in &rows {
        for issue_width in [1usize, 2, 4] {
            let mp = MachinePoint { issue_width, ..Default::default() };
            jobs.push(Job::sim(mp, workload, variant, size));
        }
    }
    let (outcomes, hits) = run_sweep_jobs(jobs.clone(), width, store);
    let results: Vec<(&Job, &Outcome)> = jobs.iter().zip(outcomes.iter()).collect();

    let mut t = Table::new(
        format!(
            "pipe-sweep: cycles vs issue width ({dhrystone_iters}/{coremark_iters} cpubench \
             iters, {} Ki elems, {} MiB memcpy)",
            elems >> 10,
            memcpy_bytes >> 20
        ),
        &["workload", "variant", "issue width", "cycles", "instret", "IPC", "dual-issue",
          "slots wasted", "verified", "Δcyc vs width 1"],
    );
    for (job, r) in &results {
        let (wl, variant) = sim_fields(job);
        // The single-issue counterpart: same workload, width 1.
        let base = results
            .iter()
            .find(|(q, _)| sim_fields(q).0 == wl && q.point.issue_width == 1)
            .map(|(_, r)| r.cycles)
            .unwrap_or(r.cycles);
        let delta = if job.point.issue_width == 1 {
            "baseline".to_string()
        } else {
            format!("{:+.1}%", (1.0 - r.cycles as f64 / base as f64) * 100.0)
        };
        t.row(&[
            wl.to_string(),
            variant.to_string(),
            job.point.issue_width.to_string(),
            r.cycles.to_string(),
            r.instret.to_string(),
            format!("{:.3}", r.ipc()),
            r.metric("dual_issue_pairs").to_string(),
            r.metric("issue_slots_wasted").to_string(),
            outcome_verified_cell(r),
            delta,
        ]);
    }
    t.note("issue width 1 rows are the paper's single-issue pipeline (Table 1 timing)");
    t.note("Δcyc is the cycle reduction vs the width-1 row; instret is identical by construction");
    t.note("rules: in-order, scoreboarded; one data-port access and one issue per SIMD unit per \
            cycle; div/rem issue alone; a taken branch ends its group (DESIGN.md §5)");
    t.note(format!("result store: {hits} cache hits / {} points", results.len()));
    t
}

/// memcpy() rate quoted in §4.1 prose at the default configuration.
pub fn memcpy_headline(scale: Scale) -> Table {
    let bytes = scale.memcpy_bytes();
    let r = memcpy_point(256, 16384, bytes);
    let mut t = Table::new("§4.1 headline memcpy (VLEN=256, LLC 16384-bit)", &["metric", "value"]);
    t.row(&["rate".into(), fmt_rate(r.throughput.bytes_per_second())]);
    t.row(&["bytes/cycle".into(), format!("{:.2}", r.throughput.bytes_per_cycle())]);
    t.row(&["IPC".into(), format!("{:.2}", r.throughput.ipc())]);
    t.row(&["verified".into(), r.verified_cell()]);
    t.row(&["DL1 alloc-no-fetch".into(), r.mem.dl1.alloc_no_fetch.to_string()]);
    t.row(&["DRAM mean burst".into(), format!("{:.0} B", r.mem.dram.mean_burst_bytes())]);
    t.note("paper: 0.69 GB/s at this configuration");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fast smoke tests; full calibration lives in
    // rust/tests/figures_calibration.rs and the benches.

    #[test]
    fn table1_prints_selected_config() {
        let t = table1();
        let r = t.render();
        assert!(r.contains("16384-bit blocks"));
        assert!(r.contains("NRU"));
    }

    #[test]
    fn fig5_semantics() {
        let t = fig5();
        let r = t.render();
        assert!(r.contains("[1, 2, 3, 4, 5, 6, 7, 8]"));
        assert!(r.contains("[9, 10, 11, 12, 13, 14, 15, 16]"));
    }

    #[test]
    fn fig6_trace_shows_overlap() {
        let s = fig6();
        assert!(s.contains("c2.i0") || s.contains("sort"), "{s}");
        assert!(s.contains('#'));
    }

    #[test]
    fn mem_sweep_reports_blocking_baseline_and_gains() {
        // Tiny sizes: this is a smoke test of the grid/derived columns;
        // the calibrated improvement bands live in
        // rust/tests/mem_bandwidth.rs and the full curve in CI's
        // BENCH_mem.json.
        let store = Mutex::new(ResultStore::in_memory());
        let t = mem_sweep_sized(256 * 1024, 16 * 1024, Parallelism::auto(), &store);
        let r = t.render();
        assert!(r.contains("memcpy") && r.contains("stream-copy") && r.contains("prefix"));
        assert!(r.contains("baseline"));
        assert!(r.contains('%'), "non-blocking rows carry a Δcyc percentage");
        assert!(!r.contains("false"), "every point must verify");
        assert!(r.contains("0 cache hits / 18 points"), "first run simulates everything:\n{r}");
        assert_eq!(store.lock().unwrap().completed(), 18, "every point lands in the store");
    }

    #[test]
    fn pipe_sweep_reports_width_one_baseline_and_gains() {
        // Tiny sizes: a smoke test of the grid/derived columns; the
        // calibrated >=15% bands live in rust/tests/pipeline.rs and the
        // full curve in CI's BENCH_pipeline.json.
        let store = Mutex::new(ResultStore::in_memory());
        let t = pipe_sweep_sized(40, 10, 4 * 1024, 256 * 1024, Parallelism::auto(), &store);
        let r = t.render();
        assert!(r.contains("dhrystone") && r.contains("stream-copy") && r.contains("memcpy"));
        assert!(r.contains("baseline"));
        assert!(r.contains('%'), "superscalar rows carry a Δcyc percentage");
        assert!(!r.contains("false"), "every point must verify");

        // Re-running against the same store is pure cache: no point
        // simulates twice, and every derived column reproduces exactly.
        let t2 = pipe_sweep_sized(40, 10, 4 * 1024, 256 * 1024, Parallelism::auto(), &store);
        let r2 = t2.render();
        assert!(r2.contains("15 cache hits / 15 points"), "{r2}");
        let body = |s: &str| {
            s.lines().filter(|l| !l.contains("cache hits")).collect::<Vec<_>>().join("\n")
        };
        assert_eq!(body(&r), body(&r2), "cached rerun reproduces the table");
    }

    #[test]
    fn discussion_ratios() {
        let t = discussion();
        let r = t.render();
        assert!(r.contains("13×"));
        assert!(r.contains("4.3×"));
    }
}
