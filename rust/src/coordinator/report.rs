//! Report tables: the experiment drivers produce `Table`s which render
//! as aligned text (terminal), markdown (EXPERIMENTS.md), or JSON
//! (`--json`, for mechanical capture of bench trajectories).

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes printed under the table (calibration comments,
    /// paper reference values, substitutions).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// JSON rendering (for `--json` and BENCH_*.json capture): one
    /// object with `title`, `headers`, `rows` (array of string arrays)
    /// and `notes`. Hand-rolled — the default build carries no serde.
    pub fn render_json(&self) -> String {
        fn arr(items: &[String]) -> String {
            let cells: Vec<String> =
                items.iter().map(|s| format!("\"{}\"", json_escape(s))).collect();
            format!("[{}]", cells.join(","))
        }
        let rows: Vec<String> = self.rows.iter().map(|r| arr(r.as_slice())).collect();
        format!(
            "{{\"title\":\"{}\",\"headers\":{},\"rows\":[{}],\"notes\":{}}}",
            json_escape(&self.title),
            arr(&self.headers),
            rows.join(","),
            arr(&self.notes),
        )
    }

    /// JSON array of several tables (what `all --json` emits).
    pub fn render_json_array(tables: &[Table]) -> String {
        let items: Vec<String> = tables.iter().map(|t| t.render_json()).collect();
        format!("[{}]", items.join(","))
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        if !self.notes.is_empty() {
            out.push('\n');
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out.push('\n');
        out
    }
}

/// Escape a string for inclusion in a JSON string literal (shared with
/// the service's wire protocol and result store, `crate::service`).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.note("a note");
        t
    }

    #[test]
    fn text_alignment() {
        let r = sample().render();
        assert!(r.contains("== Demo =="));
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[1].starts_with("a    bbbb"));
        assert!(r.contains("note: a note"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().render_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | bbbb |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn json_shape() {
        let j = sample().render_json();
        assert_eq!(
            j,
            "{\"title\":\"Demo\",\"headers\":[\"a\",\"bbbb\"],\
             \"rows\":[[\"1\",\"2\"],[\"333\",\"4\"]],\"notes\":[\"a note\"]}"
        );
    }

    #[test]
    fn json_escapes_special_characters() {
        let mut t = Table::new("q\"uote\\and\nnewline", &["h"]);
        t.row(&["\t".into()]);
        t.note("ctrl\u{1}");
        let j = t.render_json();
        assert!(j.contains("q\\\"uote\\\\and\\nnewline"), "{j}");
        assert!(j.contains("[\"\\t\"]"), "{j}");
        assert!(j.contains("ctrl\\u0001"), "{j}");
    }

    #[test]
    fn json_array_wraps_tables() {
        let j = Table::render_json_array(&[sample(), sample()]);
        assert!(j.starts_with("[{") && j.ends_with("}]"), "{j}");
        assert_eq!(j.matches("\"title\":\"Demo\"").count(), 2);
    }
}
