//! Report tables: the experiment drivers produce `Table`s which render
//! as aligned text (terminal) or markdown (EXPERIMENTS.md).

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes printed under the table (calibration comments,
    /// paper reference values, substitutions).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        if !self.notes.is_empty() {
            out.push('\n');
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.note("a note");
        t
    }

    #[test]
    fn text_alignment() {
        let r = sample().render();
        assert!(r.contains("== Demo =="));
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[1].starts_with("a    bbbb"));
        assert!(r.contains("note: a note"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().render_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a | bbbb |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
