//! Parallel sweep driver: design-space points (Fig. 3) are independent
//! simulations, so they run on OS threads. Each thread constructs its own
//! `Core` (cores are intentionally not `Send` because of the optional
//! PJRT-backed units; the *inputs* to a sweep are plain data).

/// Map `f` over `items` in parallel, preserving order. `f` runs on a
/// fresh thread per item (sweeps have ≤ a dozen points; no pool needed).
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .into_iter()
            .map(|item| scope.spawn(|| f(item)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("sweep thread panicked")).collect()
    })
}

/// Like [`parallel_map`], but runs at most `max_threads` workers pulling
/// items from a shared queue — no per-item thread and no chunk barriers,
/// so heterogeneous grids (the `run-workload` sweeps) keep every worker
/// busy until the queue drains. Preserves input order in the output.
pub fn parallel_map_bounded<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let inputs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = max_threads.clamp(1, n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().expect("input lock").take().expect("taken once");
                let r = f(item);
                *outputs[i].lock().expect("output lock") = Some(r);
            });
        }
    });
    outputs
        .into_iter()
        .map(|m| m.into_inner().expect("worker finished").expect("slot filled"))
        .collect()
}

/// Sequential fallback used when determinism of log interleaving matters.
pub fn serial_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    F: Fn(T) -> R,
{
    items.into_iter().map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..16).collect(), |i: i32| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_simulations_in_threads() {
        use crate::core::Core;
        let out = parallel_map(vec![128usize, 256], |vlen| {
            let mut core = Core::for_vlen(vlen);
            let r = crate::workloads::memcpy::run(&mut core, 16 * 1024, true).unwrap();
            (vlen, r.verified)
        });
        assert!(out.iter().all(|(_, ok)| *ok));
    }

    #[test]
    #[should_panic(expected = "sweep thread panicked")]
    fn propagates_panics() {
        parallel_map(vec![1], |_: i32| -> i32 { panic!("boom") });
    }

    #[test]
    fn bounded_preserves_order_with_fewer_workers_than_items() {
        let out = parallel_map_bounded((0..100).collect(), 3, |i: i32| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(parallel_map_bounded(Vec::new(), 4, |i: i32| i), Vec::<i32>::new());
        // A worker count above the item count is clamped, not an error.
        assert_eq!(parallel_map_bounded(vec![7], 64, |i: i32| i + 1), vec![8]);
    }
}
