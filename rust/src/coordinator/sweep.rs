//! Parallel sweep driver: design-space points (Fig. 3) are independent
//! simulations, so they run on OS threads. Each thread constructs its own
//! `Core` (cores are intentionally not `Send` because of the optional
//! PJRT-backed units; the *inputs* to a sweep are plain data).
//!
//! [`MachinePoint`] is the registry of machine-configuration sweep axes
//! (`vlen`, `llc-block`, `mshrs`, `prefetch`, `channels`,
//! `issue-width`): every surface that sweeps configurations — the
//! `run-workload` CLI grid, the `mem-sweep`/`pipe-sweep` experiments
//! and the fuzz campaign grid — goes through it, so adding an axis here
//! makes it sweepable everywhere at once.

use crate::machine::Machine;

/// One machine-configuration point of a sweep grid: the sweepable axes
/// beyond workload/variant/size. `Default` is the paper's Table-1
/// machine (blocking port, no prefetch, one DRAM channel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachinePoint {
    /// Vector register width in bits.
    pub vlen: usize,
    /// LLC block size in bits (capacity held constant).
    pub llc_block: usize,
    /// MSHRs at DL1 and the LLC (1 = blocking).
    pub mshrs: usize,
    /// Next-N-line prefetch depth (0 = off).
    pub prefetch: usize,
    /// Independent DRAM channels.
    pub channels: usize,
    /// In-order issue width of the core pipeline (1 = the paper's
    /// single-issue model; 2/4 = the superscalar issue-group model).
    pub issue_width: usize,
}

impl Default for MachinePoint {
    fn default() -> Self {
        Self { vlen: 256, llc_block: 16384, mshrs: 1, prefetch: 0, channels: 1, issue_width: 1 }
    }
}

impl MachinePoint {
    /// The machine-configuration axis names accepted by `--sweep`.
    pub const AXES: &'static [&'static str] =
        &["vlen", "llc-block", "mshrs", "prefetch", "channels", "issue-width"];

    /// Whether `axis` names a machine axis, including the underscore
    /// spellings (`llc_block`, `issue_width`) the `--sweep` parser also
    /// accepts.
    pub fn is_axis(axis: &str) -> bool {
        Self::AXES.contains(&axis) || axis == "llc_block" || axis == "issue_width"
    }

    /// Set one axis by CLI name; `false` for an unknown axis.
    pub fn set(&mut self, axis: &str, value: usize) -> bool {
        match axis {
            "vlen" => self.vlen = value,
            "llc-block" | "llc_block" => self.llc_block = value,
            "mshrs" => self.mshrs = value,
            "prefetch" => self.prefetch = value,
            "channels" => self.channels = value,
            "issue-width" | "issue_width" => self.issue_width = value,
            _ => return false,
        }
        true
    }

    /// Materialise the configured [`Machine`].
    pub fn machine(&self) -> Machine {
        Machine::for_vlen(self.vlen)
            .llc_block(self.llc_block)
            .mshrs(self.mshrs)
            .prefetch_depth(self.prefetch)
            .dram_channels(self.channels)
            .issue_width(self.issue_width)
    }

    /// Stable canonical serialization of this point: a JSON object with
    /// the keys in sorted order and integer values only (no floats, so
    /// no formatting drift across Rust versions or platforms). This is
    /// the byte string the service hashes to key its content-addressed
    /// result store ([`crate::service`]), so its exact shape is pinned
    /// by a unit test — changing it invalidates every stored result.
    pub fn canonical(&self) -> String {
        format!(
            "{{\"channels\":{},\"issue_width\":{},\"llc_block\":{},\"mshrs\":{},\
             \"prefetch\":{},\"vlen\":{}}}",
            self.channels, self.issue_width, self.llc_block, self.mshrs, self.prefetch, self.vlen
        )
    }

    /// Parse a point back out of the object produced by
    /// [`MachinePoint::canonical`] (used by the result store when
    /// re-loading persisted records).
    pub fn from_canonical_fields(
        mut get: impl FnMut(&str) -> Option<usize>,
    ) -> Result<Self, String> {
        let mut p = MachinePoint::default();
        for axis in ["channels", "issue_width", "llc_block", "mshrs", "prefetch", "vlen"] {
            let v = get(axis).ok_or_else(|| format!("machine point missing field '{axis}'"))?;
            assert!(p.set(axis, v), "canonical field names are valid axes");
        }
        Ok(p)
    }

    /// Reject values the simulator cannot represent, before any sweep
    /// thread is spawned (e.g. `llc-block 0` would divide by zero in the
    /// geometry math; `vlen 100` fails cache-config validation).
    pub fn validate(&self) -> Result<(), String> {
        use crate::simd::MAX_VLEN_BITS;
        if !self.vlen.is_power_of_two() || !(64..=MAX_VLEN_BITS).contains(&self.vlen) {
            return Err(format!(
                "vlen {} must be a power of two in 64..={MAX_VLEN_BITS}",
                self.vlen
            ));
        }
        if !self.llc_block.is_power_of_two()
            || self.llc_block < self.vlen
            || self.llc_block > 512 * 1024
        {
            return Err(format!(
                "llc-block {} must be a power of two in {}..=524288 (>= vlen)",
                self.llc_block, self.vlen
            ));
        }
        if self.mshrs == 0 || self.mshrs > 64 {
            return Err(format!("mshrs {} must be in 1..=64", self.mshrs));
        }
        if self.prefetch > 64 {
            return Err(format!("prefetch {} must be at most 64 lines", self.prefetch));
        }
        if self.channels == 0 || self.channels > 16 {
            return Err(format!("channels {} must be in 1..=16", self.channels));
        }
        if ![1, 2, 4].contains(&self.issue_width) {
            return Err(format!("issue-width {} must be 1, 2 or 4", self.issue_width));
        }
        self.machine()
            .validate()
            .map_err(|e| format!("vlen {} / llc-block {}: {e}", self.vlen, self.llc_block))
    }
}

/// Worker-pool width for a sweep surface, threaded *by value* through
/// every call-site (experiment drivers via [`super::Scale`], the fuzz
/// campaign via `FuzzConfig`, the service via its grid options).
///
/// This used to be a process-global `set_jobs`/`jobs` atomic; with the
/// long-running service mode, concurrent surfaces (service workers and
/// a one-shot CLI invocation, or two submissions with different
/// widths) must not fight over shared mutable state, so the value now
/// travels with the request. The CLI's `--jobs N` flag behaviour is
/// unchanged: it produces `Parallelism::fixed(n)`, the default is
/// [`Parallelism::auto`] (the host's available parallelism).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Parallelism(usize);

impl Parallelism {
    /// Use the host's available parallelism (the default).
    pub fn auto() -> Self {
        Self(0)
    }

    /// Exactly `n` workers (the `--jobs N` flag); `0` behaves as auto.
    pub fn fixed(n: usize) -> Self {
        Self(n)
    }

    /// The worker count to pass to [`parallel_map_bounded`].
    pub fn workers(self) -> usize {
        match self.0 {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            n => n,
        }
    }
}

/// 64-bit FNV-1a over `bytes`: the stable, dependency-free hash behind
/// the service's content-addressed result store. The constants are the
/// published FNV parameters, so the digest of a canonical job string is
/// identical across platforms, Rust versions, and process runs
/// (`std::hash` makes none of those promises).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Expand `axis=v1,v2,...` sweep specs (machine axes only) into a grid
/// of machine points, starting from `base`. Shared by the
/// `run-workload`/`fuzz`/`sweep-grid` CLI surfaces and the service's
/// JSON `submit` handler.
pub fn machine_grid(base: MachinePoint, sweeps: &[&str]) -> Result<Vec<MachinePoint>, String> {
    let mut grid = vec![base];
    for spec in sweeps {
        let (axis, vals) = spec
            .split_once('=')
            .ok_or_else(|| format!("sweep spec expects axis=v1,v2,..., got '{spec}'"))?;
        if !MachinePoint::is_axis(axis) {
            return Err(format!(
                "unknown machine sweep axis '{axis}' (axes: {})",
                MachinePoint::AXES.join(", ")
            ));
        }
        let values: Vec<usize> = vals
            .split(',')
            .map(|v| {
                v.trim()
                    .parse()
                    .map_err(|_| format!("bad {axis} value '{v}' in sweep spec '{spec}'"))
            })
            .collect::<Result<_, _>>()?;
        let mut expanded = Vec::with_capacity(grid.len() * values.len());
        for mp in &grid {
            for &v in &values {
                let mut mp = *mp;
                mp.set(axis, v);
                expanded.push(mp);
            }
        }
        grid = expanded;
    }
    Ok(grid)
}

/// Run `f` over `items` on at most `max_threads` workers pulling items
/// from a shared queue — no per-item thread and no chunk barriers, so
/// heterogeneous grids (the `run-workload` sweeps) keep every worker
/// busy until the queue drains. Preserves input order in the output.
/// Every sweep call-site in the repository routes through this function
/// (with [`Parallelism::workers`] as the width), so `--jobs 1`
/// serialises everything.
pub fn parallel_map_bounded<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let inputs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = max_threads.clamp(1, n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().expect("input lock").take().expect("taken once");
                let r = f(item);
                *outputs[i].lock().expect("output lock") = Some(r);
            });
        }
    });
    outputs
        .into_iter()
        .map(|m| m.into_inner().expect("worker finished").expect("slot filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map_bounded((0..16).collect(), Parallelism::auto().workers(), |i: i32| {
            i * i
        });
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_simulations_in_bounded_pool_preserving_order() {
        use crate::core::Core;
        // Two workers over four heterogeneous simulation points: results
        // must come back in input order regardless of finish order.
        let vlens = vec![128usize, 256, 512, 1024];
        let out = parallel_map_bounded(vlens.clone(), 2, |vlen| {
            let mut core = Core::for_vlen(vlen);
            let r = crate::workloads::memcpy::run(&mut core, 16 * 1024, true).unwrap();
            (vlen, r.verified)
        });
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vlens);
        assert!(out.iter().all(|(_, ok)| *ok));
    }

    #[test]
    fn single_worker_is_fully_serial_and_ordered() {
        let out = parallel_map_bounded((0..32).collect(), 1, |i: i32| i + 100);
        assert_eq!(out, (0..32).map(|i| i + 100).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_is_a_value_not_a_global() {
        assert_eq!(Parallelism::fixed(3).workers(), 3);
        assert!(Parallelism::auto().workers() >= 1, "default derives from available parallelism");
        assert_eq!(Parallelism::fixed(0), Parallelism::auto(), "0 behaves as auto");
        // Two surfaces can hold different widths at once — the exact
        // property the old process-global `set_jobs` could not provide.
        let (a, b) = (Parallelism::fixed(1), Parallelism::fixed(7));
        assert_eq!((a.workers(), b.workers()), (1, 7));
        assert_eq!(Parallelism::default(), Parallelism::auto());
    }

    #[test]
    fn canonical_point_serialization_is_sorted_and_integer_only() {
        let p = MachinePoint::default();
        assert_eq!(
            p.canonical(),
            "{\"channels\":1,\"issue_width\":1,\"llc_block\":16384,\"mshrs\":1,\
             \"prefetch\":0,\"vlen\":256}"
        );
        // Round-trips through the canonical field reader.
        let q = MachinePoint::from_canonical_fields(|axis| match axis {
            "channels" => Some(1),
            "issue_width" => Some(1),
            "llc_block" => Some(16384),
            "mshrs" => Some(1),
            "prefetch" => Some(0),
            "vlen" => Some(256),
            _ => None,
        })
        .unwrap();
        assert_eq!(p, q);
        assert!(MachinePoint::from_canonical_fields(|_| None).is_err());
    }

    #[test]
    fn default_paper_machine_hash_is_pinned() {
        // The content-addressed store keys on this digest: if it moves,
        // every persisted result silently misses. Pin the exact value
        // for the default paper machine (Table 1).
        let digest = fnv1a64(MachinePoint::default().canonical().as_bytes());
        assert_eq!(
            digest, 0xaa5d_a4e6_15c8_15af,
            "canonical hash of the paper machine moved: {digest:#018x} — this invalidates \
             every existing result store; bump service::CODE_VERSION if intentional"
        );
        // FNV-1a sanity against published test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn machine_grid_expands_cartesian_products() {
        let grid = machine_grid(MachinePoint::default(), &["vlen=128,256", "mshrs=1,4"]).unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0], MachinePoint { vlen: 128, mshrs: 1, ..Default::default() });
        assert_eq!(grid[3], MachinePoint { vlen: 256, mshrs: 4, ..Default::default() });
        assert!(machine_grid(MachinePoint::default(), &["bogus=1"]).is_err());
        assert!(machine_grid(MachinePoint::default(), &["vlen=x"]).is_err());
        assert!(machine_grid(MachinePoint::default(), &["vlen"]).is_err());
    }

    #[test]
    fn machine_point_axes_round_trip() {
        let mut p = MachinePoint::default();
        assert!(p.validate().is_ok(), "default point is the paper machine");
        for (axis, v) in [
            ("vlen", 512),
            ("llc-block", 4096),
            ("mshrs", 4),
            ("prefetch", 2),
            ("channels", 2),
            ("issue-width", 2),
        ] {
            assert!(MachinePoint::AXES.contains(&axis));
            assert!(MachinePoint::is_axis(axis));
            assert!(p.set(axis, v), "axis {axis} must be known");
        }
        assert!(p.validate().is_ok());
        let m = p.machine();
        assert_eq!(m.core_config().vlen_bits, 512);
        assert_eq!(m.mem_config().llc.block_bits, 4096);
        assert_eq!(m.mem_config().dl1_mshrs, 4);
        assert_eq!(m.mem_config().prefetch_depth, 2);
        assert_eq!(m.mem_config().dram.channels, 2);
        assert_eq!(m.core_config().issue_width, 2);
        assert!(!p.set("no-such-axis", 1));
        assert!(!MachinePoint::is_axis("no-such-axis"));
        // Underscore spellings work everywhere the dash forms do.
        assert!(MachinePoint::is_axis("issue_width") && MachinePoint::is_axis("llc_block"));
        assert!(p.set("issue_width", 4));
        assert_eq!(p.issue_width, 4);
    }

    #[test]
    fn machine_point_rejects_unrepresentable_values() {
        let bad = MachinePoint { vlen: 100, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = MachinePoint { llc_block: 128, ..Default::default() }; // < vlen
        assert!(bad.validate().is_err());
        let bad = MachinePoint { mshrs: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = MachinePoint { channels: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        for issue_width in [0, 3, 8] {
            let bad = MachinePoint { issue_width, ..Default::default() };
            assert!(bad.validate().is_err(), "issue-width {issue_width} must be rejected");
        }
    }

    #[test]
    fn bounded_preserves_order_with_fewer_workers_than_items() {
        let out = parallel_map_bounded((0..100).collect(), 3, |i: i32| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(parallel_map_bounded(Vec::new(), 4, |i: i32| i), Vec::<i32>::new());
        // A worker count above the item count is clamped, not an error.
        assert_eq!(parallel_map_bounded(vec![7], 64, |i: i32| i + 1), vec![8]);
    }
}
