//! Parallel sweep driver: design-space points (Fig. 3) are independent
//! simulations, so they run on OS threads. Each thread constructs its own
//! `Core` (cores are intentionally not `Send` because of the optional
//! PJRT-backed units; the *inputs* to a sweep are plain data).

/// Map `f` over `items` in parallel, preserving order. `f` runs on a
/// fresh thread per item (sweeps have ≤ a dozen points; no pool needed).
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .into_iter()
            .map(|item| scope.spawn(|| f(item)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("sweep thread panicked")).collect()
    })
}

/// Sequential fallback used when determinism of log interleaving matters.
pub fn serial_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    F: Fn(T) -> R,
{
    items.into_iter().map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map((0..16).collect(), |i: i32| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_simulations_in_threads() {
        use crate::core::Core;
        let out = parallel_map(vec![128usize, 256], |vlen| {
            let mut core = Core::for_vlen(vlen);
            let r = crate::workloads::memcpy::run(&mut core, 16 * 1024, true).unwrap();
            (vlen, r.verified)
        });
        assert!(out.iter().all(|(_, ok)| *ok));
    }

    #[test]
    #[should_panic(expected = "sweep thread panicked")]
    fn propagates_panics() {
        parallel_map(vec![1], |_: i32| -> i32 { panic!("boom") });
    }
}
