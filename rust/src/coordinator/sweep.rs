//! Parallel sweep driver: design-space points (Fig. 3) are independent
//! simulations, so they run on OS threads. Each thread constructs its own
//! `Core` (cores are intentionally not `Send` because of the optional
//! PJRT-backed units; the *inputs* to a sweep are plain data).
//!
//! [`MachinePoint`] is the registry of machine-configuration sweep axes
//! (`vlen`, `llc-block`, `mshrs`, `prefetch`, `channels`,
//! `issue-width`): every surface that sweeps configurations — the
//! `run-workload` CLI grid, the `mem-sweep`/`pipe-sweep` experiments
//! and the fuzz campaign grid — goes through it, so adding an axis here
//! makes it sweepable everywhere at once.

use crate::machine::Machine;

/// One machine-configuration point of a sweep grid: the sweepable axes
/// beyond workload/variant/size. `Default` is the paper's Table-1
/// machine (blocking port, no prefetch, one DRAM channel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachinePoint {
    /// Vector register width in bits.
    pub vlen: usize,
    /// LLC block size in bits (capacity held constant).
    pub llc_block: usize,
    /// MSHRs at DL1 and the LLC (1 = blocking).
    pub mshrs: usize,
    /// Next-N-line prefetch depth (0 = off).
    pub prefetch: usize,
    /// Independent DRAM channels.
    pub channels: usize,
    /// In-order issue width of the core pipeline (1 = the paper's
    /// single-issue model; 2/4 = the superscalar issue-group model).
    pub issue_width: usize,
}

impl Default for MachinePoint {
    fn default() -> Self {
        Self { vlen: 256, llc_block: 16384, mshrs: 1, prefetch: 0, channels: 1, issue_width: 1 }
    }
}

impl MachinePoint {
    /// The machine-configuration axis names accepted by `--sweep`.
    pub const AXES: &'static [&'static str] =
        &["vlen", "llc-block", "mshrs", "prefetch", "channels", "issue-width"];

    /// Whether `axis` names a machine axis, including the underscore
    /// spellings (`llc_block`, `issue_width`) the `--sweep` parser also
    /// accepts.
    pub fn is_axis(axis: &str) -> bool {
        Self::AXES.contains(&axis) || axis == "llc_block" || axis == "issue_width"
    }

    /// Set one axis by CLI name; `false` for an unknown axis.
    pub fn set(&mut self, axis: &str, value: usize) -> bool {
        match axis {
            "vlen" => self.vlen = value,
            "llc-block" | "llc_block" => self.llc_block = value,
            "mshrs" => self.mshrs = value,
            "prefetch" => self.prefetch = value,
            "channels" => self.channels = value,
            "issue-width" | "issue_width" => self.issue_width = value,
            _ => return false,
        }
        true
    }

    /// Materialise the configured [`Machine`].
    pub fn machine(&self) -> Machine {
        Machine::for_vlen(self.vlen)
            .llc_block(self.llc_block)
            .mshrs(self.mshrs)
            .prefetch_depth(self.prefetch)
            .dram_channels(self.channels)
            .issue_width(self.issue_width)
    }

    /// Reject values the simulator cannot represent, before any sweep
    /// thread is spawned (e.g. `llc-block 0` would divide by zero in the
    /// geometry math; `vlen 100` fails cache-config validation).
    pub fn validate(&self) -> Result<(), String> {
        use crate::simd::MAX_VLEN_BITS;
        if !self.vlen.is_power_of_two() || !(64..=MAX_VLEN_BITS).contains(&self.vlen) {
            return Err(format!(
                "vlen {} must be a power of two in 64..={MAX_VLEN_BITS}",
                self.vlen
            ));
        }
        if !self.llc_block.is_power_of_two()
            || self.llc_block < self.vlen
            || self.llc_block > 512 * 1024
        {
            return Err(format!(
                "llc-block {} must be a power of two in {}..=524288 (>= vlen)",
                self.llc_block, self.vlen
            ));
        }
        if self.mshrs == 0 || self.mshrs > 64 {
            return Err(format!("mshrs {} must be in 1..=64", self.mshrs));
        }
        if self.prefetch > 64 {
            return Err(format!("prefetch {} must be at most 64 lines", self.prefetch));
        }
        if self.channels == 0 || self.channels > 16 {
            return Err(format!("channels {} must be in 1..=16", self.channels));
        }
        if ![1, 2, 4].contains(&self.issue_width) {
            return Err(format!("issue-width {} must be 1, 2 or 4", self.issue_width));
        }
        self.machine()
            .validate()
            .map_err(|e| format!("vlen {} / llc-block {}: {e}", self.vlen, self.llc_block))
    }
}

/// Process-wide worker-pool width for every sweep surface. `0` (the
/// default) means "use the host's available parallelism"; the CLI's
/// global `--jobs N` flag overrides it via [`set_jobs`].
static JOBS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Override the default sweep worker count (the CLI's `--jobs` flag).
/// `0` restores the available-parallelism default.
pub fn set_jobs(n: usize) {
    JOBS.store(n, std::sync::atomic::Ordering::Relaxed);
}

/// The worker count every sweep call-site passes to
/// [`parallel_map_bounded`]: the `--jobs` override if set, otherwise
/// the host's available parallelism.
pub fn jobs() -> usize {
    match JOBS.load(std::sync::atomic::Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n,
    }
}

/// Run `f` over `items` on at most `max_threads` workers pulling items
/// from a shared queue — no per-item thread and no chunk barriers, so
/// heterogeneous grids (the `run-workload` sweeps) keep every worker
/// busy until the queue drains. Preserves input order in the output.
/// Every sweep call-site in the repository routes through this function
/// (with [`jobs`] as the width), so `--jobs 1` serialises everything.
pub fn parallel_map_bounded<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let inputs: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = max_threads.clamp(1, n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().expect("input lock").take().expect("taken once");
                let r = f(item);
                *outputs[i].lock().expect("output lock") = Some(r);
            });
        }
    });
    outputs
        .into_iter()
        .map(|m| m.into_inner().expect("worker finished").expect("slot filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map_bounded((0..16).collect(), jobs(), |i: i32| i * i);
        assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_simulations_in_bounded_pool_preserving_order() {
        use crate::core::Core;
        // Two workers over four heterogeneous simulation points: results
        // must come back in input order regardless of finish order.
        let vlens = vec![128usize, 256, 512, 1024];
        let out = parallel_map_bounded(vlens.clone(), 2, |vlen| {
            let mut core = Core::for_vlen(vlen);
            let r = crate::workloads::memcpy::run(&mut core, 16 * 1024, true).unwrap();
            (vlen, r.verified)
        });
        assert_eq!(out.iter().map(|(v, _)| *v).collect::<Vec<_>>(), vlens);
        assert!(out.iter().all(|(_, ok)| *ok));
    }

    #[test]
    fn single_worker_is_fully_serial_and_ordered() {
        let out = parallel_map_bounded((0..32).collect(), 1, |i: i32| i + 100);
        assert_eq!(out, (0..32).map(|i| i + 100).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_override_roundtrip() {
        set_jobs(3);
        assert_eq!(jobs(), 3);
        set_jobs(0);
        assert!(jobs() >= 1, "default derives from available parallelism");
    }

    #[test]
    fn machine_point_axes_round_trip() {
        let mut p = MachinePoint::default();
        assert!(p.validate().is_ok(), "default point is the paper machine");
        for (axis, v) in [
            ("vlen", 512),
            ("llc-block", 4096),
            ("mshrs", 4),
            ("prefetch", 2),
            ("channels", 2),
            ("issue-width", 2),
        ] {
            assert!(MachinePoint::AXES.contains(&axis));
            assert!(MachinePoint::is_axis(axis));
            assert!(p.set(axis, v), "axis {axis} must be known");
        }
        assert!(p.validate().is_ok());
        let m = p.machine();
        assert_eq!(m.core_config().vlen_bits, 512);
        assert_eq!(m.mem_config().llc.block_bits, 4096);
        assert_eq!(m.mem_config().dl1_mshrs, 4);
        assert_eq!(m.mem_config().prefetch_depth, 2);
        assert_eq!(m.mem_config().dram.channels, 2);
        assert_eq!(m.core_config().issue_width, 2);
        assert!(!p.set("no-such-axis", 1));
        assert!(!MachinePoint::is_axis("no-such-axis"));
        // Underscore spellings work everywhere the dash forms do.
        assert!(MachinePoint::is_axis("issue_width") && MachinePoint::is_axis("llc_block"));
        assert!(p.set("issue_width", 4));
        assert_eq!(p.issue_width, 4);
    }

    #[test]
    fn machine_point_rejects_unrepresentable_values() {
        let bad = MachinePoint { vlen: 100, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = MachinePoint { llc_block: 128, ..Default::default() }; // < vlen
        assert!(bad.validate().is_err());
        let bad = MachinePoint { mshrs: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = MachinePoint { channels: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        for issue_width in [0, 3, 8] {
            let bad = MachinePoint { issue_width, ..Default::default() };
            assert!(bad.validate().is_err(), "issue-width {issue_width} must be rejected");
        }
    }

    #[test]
    fn bounded_preserves_order_with_fewer_workers_than_items() {
        let out = parallel_map_bounded((0..100).collect(), 3, |i: i32| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(parallel_map_bounded(Vec::new(), 4, |i: i32| i), Vec::<i32>::new());
        // A worker count above the item count is clamped, not an error.
        assert_eq!(parallel_map_bounded(vec![7], 64, |i: i32| i + 1), vec![8]);
    }
}
