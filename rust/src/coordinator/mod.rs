//! Experiment coordination: report tables, the parallel sweep driver and
//! one driver function per paper table/figure (see DESIGN.md §4 for the
//! experiment index).

pub mod experiments;
pub mod report;
pub mod sweep;

pub use experiments::Scale;
pub use report::Table;
