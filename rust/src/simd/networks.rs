//! Structural models of the paper's datapath networks.
//!
//! The Verilog templates (§2.2, Algorithm 1) build instructions out of
//! compare-and-swap (CAS) layers; the pipeline length `cN_cycles` equals
//! the number of layers. We model networks the same way — as explicit
//! layer lists — so that (a) instruction latencies are *derived from the
//! structure*, exactly like the hardware, and (b) tests can check the
//! structural model against functional oracles.
//!
//! Networks implemented:
//! - Batcher bitonic sorter (`c2_sort`) — Θ(log²N) layers; 6 layers for
//!   N=8, 3 for N=4 (matching §6: "sorts 8 elements in 6 cycles" and
//!   Algorithm 1's `c1_cycles 3` for 4 inputs).
//! - Odd-even merge block (`c1_merge`) — the last log₂(N) layers of
//!   odd-even mergesort plus one leading layer for progressive merging of
//!   arbitrarily long lists (Fig. 5).
//! - Hillis-Steele prefix-sum (`c3_prefix`) — log₂(N) shift-add layers
//!   plus one carry layer (Fig. 7).

/// One compare-and-swap: indices `(lo, hi)`; after the CAS,
/// `out[lo] = min(in[lo], in[hi])`, `out[hi] = max(...)`.
pub type Cas = (usize, usize);

/// A network is a sequence of parallel layers; each layer's CAS pairs are
/// disjoint (checked by [`validate_layers`]), i.e. executable in one cycle.
pub type CasLayers = Vec<Vec<Cas>>;

/// Batcher's bitonic sorting network for `n` inputs (n = power of two).
/// Layer count is k(k+1)/2 for n = 2^k.
pub fn bitonic_sort_network(n: usize) -> CasLayers {
    assert!(n.is_power_of_two() && n >= 2);
    let mut layers: CasLayers = Vec::new();
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j >= 1 {
            let mut layer: Vec<Cas> = Vec::new();
            for i in 0..n {
                let l = i ^ j;
                if l > i {
                    // Direction: ascending iff bit k of i is 0.
                    if i & k == 0 {
                        layer.push((i, l));
                    } else {
                        layer.push((l, i)); // descending: swap roles
                    }
                }
            }
            layers.push(layer);
            j /= 2;
        }
        k *= 2;
    }
    layers
}

/// The odd-even *merge block*: merges two sorted halves of a 2m-element
/// input (elements `0..m` sorted ascending, `m..2m` sorted ascending).
///
/// This is the last log₂(2m) layers of Batcher's odd-even mergesort. As
/// in the paper (§4.3.1) we prepend one extra CAS layer pairing element i
/// of the first list with element m-1-i of the second, which converts the
/// concatenation of two ascending lists into a bitonic sequence — the
/// same trick that lets the instruction merge arbitrarily long lists
/// progressively (low half retired, high half recirculated).
pub fn merge_block_network(two_m: usize) -> CasLayers {
    assert!(two_m.is_power_of_two() && two_m >= 2);
    let m = two_m / 2;
    let mut layers: CasLayers = Vec::new();
    // Leading layer: (i, 2m-1-i) — reverse the second list and CAS.
    layers.push((0..m).map(|i| (i, two_m - 1 - i)).collect());
    // Then a bitonic merger: for j = m/2 ... 1, CAS (i, i+j) within
    // aligned groups.
    let mut j = m;
    while j >= 1 {
        let mut layer: Vec<Cas> = Vec::new();
        for i in 0..two_m {
            let l = i | j;
            if l != i && l < two_m {
                layer.push((i, l));
            }
        }
        // Note the j == m layer never swaps after the leading layer (the
        // halves are already min/max partitioned) but it is kept as a
        // pipeline stage, matching the paper's depth of log₂(N) merge
        // layers plus one leading stage (§4.3.1, Fig. 6).
        layers.push(layer);
        j /= 2;
    }
    layers
}

/// Apply one CAS layer.
pub fn apply_layer(values: &mut [i32], layer: &[Cas]) {
    for &(lo, hi) in layer {
        if values[lo] > values[hi] {
            values.swap(lo, hi);
        }
    }
}

/// Run a full network over `values`.
pub fn run_network(values: &mut [i32], layers: &CasLayers) {
    for layer in layers {
        apply_layer(values, layer);
    }
}

/// Check the single-cycle property: within each layer every index is
/// touched at most once. Returns the offending layer index on failure.
pub fn validate_layers(n: usize, layers: &CasLayers) -> Result<(), usize> {
    for (li, layer) in layers.iter().enumerate() {
        let mut used = vec![false; n];
        for &(a, b) in layer {
            if a >= n || b >= n || used[a] || used[b] || a == b {
                return Err(li);
            }
            used[a] = true;
            used[b] = true;
        }
    }
    Ok(())
}

/// Hillis-Steele inclusive prefix sum, expressed as layers of
/// (dst, src, shift) add steps: layer k adds `x[i - 2^k]` into `x[i]`.
/// Returns the number of layers for an n-element vector (log₂ n), to
/// which the instruction adds one carry-in layer (Fig. 7).
pub fn hillis_steele_layer_count(n: usize) -> u64 {
    assert!(n.is_power_of_two());
    n.trailing_zeros() as u64
}

/// Functional Hillis-Steele prefix sum with carry-in; returns the output
/// vector and the new carry (= carry + total of inputs). Wrapping i32
/// arithmetic, as 32-bit adders in hardware would behave.
pub fn prefix_sum_with_carry(input: &[i32], carry: i32) -> (Vec<i32>, i32) {
    let n = input.len();
    let mut x: Vec<i32> = input.to_vec();
    let mut shift = 1;
    while shift < n {
        // One parallel layer (read the pre-layer values).
        let prev = x.clone();
        for i in shift..n {
            x[i] = prev[i].wrapping_add(prev[i - shift]);
        }
        shift *= 2;
    }
    // Carry layer: add the running total of all previous batches.
    for v in x.iter_mut() {
        *v = v.wrapping_add(carry);
    }
    let new_carry = *x.last().expect("non-empty input");
    (x, new_carry)
}

/// Total pipeline depth of the `c2_sort` instruction for `n` elements.
pub fn sort_latency(n: usize) -> u64 {
    bitonic_sort_network(n).len() as u64
}

/// Total pipeline depth of the `c1_merge` instruction for 2m elements.
pub fn merge_latency(two_m: usize) -> u64 {
    merge_block_network(two_m).len() as u64
}

/// Total pipeline depth of the `c3_prefix` instruction (log₂ n + carry).
pub fn prefix_latency(n: usize) -> u64 {
    hillis_steele_layer_count(n) + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn bitonic_depths_match_paper() {
        assert_eq!(sort_latency(4), 3, "Algorithm 1: c1_cycles = 3 for 4 inputs");
        assert_eq!(sort_latency(8), 6, "§6: 8 elements in 6 cycles");
        assert_eq!(sort_latency(16), 10);
        assert_eq!(sort_latency(32), 15);
    }

    #[test]
    fn networks_have_disjoint_layers() {
        for n in [2usize, 4, 8, 16, 32, 64] {
            validate_layers(n, &bitonic_sort_network(n))
                .unwrap_or_else(|l| panic!("bitonic n={n} layer {l} not parallel"));
            validate_layers(n, &merge_block_network(n))
                .unwrap_or_else(|l| panic!("merge n={n} layer {l} not parallel"));
        }
    }

    #[test]
    fn bitonic_sorts_random_inputs() {
        let mut rng = Xoshiro256::seeded(1);
        for n in [4usize, 8, 16, 32] {
            let net = bitonic_sort_network(n);
            for _ in 0..200 {
                let mut v = rng.vec_i32(n);
                let mut expect = v.clone();
                expect.sort_unstable();
                run_network(&mut v, &net);
                assert_eq!(v, expect, "n={n}");
            }
        }
    }

    #[test]
    fn merge_block_merges_sorted_halves() {
        let mut rng = Xoshiro256::seeded(2);
        for two_m in [4usize, 8, 16, 32] {
            let net = merge_block_network(two_m);
            for _ in 0..200 {
                let mut v = rng.vec_i32(two_m);
                let m = two_m / 2;
                v[..m].sort_unstable();
                v[m..].sort_unstable();
                let mut expect = v.clone();
                expect.sort_unstable();
                run_network(&mut v, &net);
                assert_eq!(v, expect, "two_m={two_m}");
            }
        }
    }

    #[test]
    fn merge_depth_is_log_plus_one() {
        // log2(16) layers of the mergesort tail + 1 leading layer, but the
        // leading layer replaces the first bitonic layer: total log2(N)+1-1+1.
        assert_eq!(merge_latency(16), 5, "Fig. 6 uses a 5-stage merge for 16 elems");
        assert_eq!(merge_latency(8), 4);
        assert_eq!(merge_latency(4), 3);
    }

    #[test]
    fn prefix_sum_matches_serial_oracle() {
        let mut rng = Xoshiro256::seeded(3);
        for n in [4usize, 8, 16] {
            let mut carry = 0i32;
            let mut serial_acc = 0i32;
            for _ in 0..50 {
                let input = rng.vec_i32(n);
                let (out, new_carry) = prefix_sum_with_carry(&input, carry);
                for (i, &x) in input.iter().enumerate() {
                    serial_acc = serial_acc.wrapping_add(x);
                    assert_eq!(out[i], serial_acc, "n={n} i={i}");
                }
                assert_eq!(new_carry, serial_acc);
                carry = new_carry;
            }
        }
    }

    #[test]
    fn prefix_latency_matches_fig7() {
        // Fig. 7: logN Hillis-Steele stages + 1 carry stage.
        assert_eq!(prefix_latency(8), 4);
        assert_eq!(prefix_latency(16), 5);
    }

    #[test]
    fn merge_is_stable_for_presorted_input() {
        let net = merge_block_network(16);
        let mut v: Vec<i32> = (0..16).collect();
        run_network(&mut v, &net);
        assert_eq!(v, (0..16).collect::<Vec<i32>>());
    }

    /// Property: the bitonic sorter equals `slice::sort` for every
    /// power-of-two width 2..=256 on PRNG inputs (not just the lane
    /// counts the units instantiate — the structural generator must be
    /// correct for any width a future VLEN explores).
    #[test]
    fn bitonic_equals_std_sort_all_widths() {
        for n in (1..=8).map(|k| 1usize << k) {
            let net = bitonic_sort_network(n);
            validate_layers(n, &net)
                .unwrap_or_else(|l| panic!("bitonic n={n}: layer {l} not single-cycle"));
            crate::util::proptest::check(&format!("bitonic n={n} == sort"), 24, |rng| {
                let mut v = rng.vec_i32(n);
                let mut expect = v.clone();
                expect.sort_unstable();
                run_network(&mut v, &net);
                crate::prop_assert_eq!(v, expect);
                Ok(())
            });
        }
    }

    /// Property: the merge block equals a functional merge for every
    /// power-of-two width 2..=256, on PRNG inputs with duplicate-heavy
    /// and extreme-value cases mixed in.
    #[test]
    fn merge_equals_std_merge_all_widths() {
        for two_m in (1..=8).map(|k| 1usize << k) {
            let net = merge_block_network(two_m);
            validate_layers(two_m, &net)
                .unwrap_or_else(|l| panic!("merge n={two_m}: layer {l} not single-cycle"));
            crate::util::proptest::check(&format!("merge n={two_m} == sort"), 24, |rng| {
                let m = two_m / 2;
                let mut v = match rng.below(4) {
                    0 => vec![rng.next_u32() as i32 % 3; two_m], // duplicates
                    1 => {
                        let mut v = rng.vec_i32(two_m);
                        v[0] = i32::MIN;
                        v[two_m - 1] = i32::MAX;
                        v
                    }
                    _ => rng.vec_i32(two_m),
                };
                v[..m].sort_unstable();
                v[m..].sort_unstable();
                let mut expect = v.clone();
                expect.sort_unstable();
                run_network(&mut v, &net);
                crate::prop_assert_eq!(v, expect);
                Ok(())
            });
        }
    }

    /// `validate_layers` must reject every class of mutation that would
    /// break the single-cycle property: duplicated indices within a
    /// layer, self-CAS pairs, and out-of-range wires.
    #[test]
    fn validate_layers_rejects_mutated_networks() {
        for n in [8usize, 32, 256] {
            for make in [bitonic_sort_network, merge_block_network] {
                let good = make(n);
                assert_eq!(validate_layers(n, &good), Ok(()));

                // Duplicate an existing CAS inside its own layer: the
                // touched indices collide.
                let mut dup = good.clone();
                let cas = dup[0][0];
                dup[0].push(cas);
                assert_eq!(validate_layers(n, &dup), Err(0), "duplicate CAS n={n}");

                // A self-compare (a, a) is not a valid CAS.
                let mut selfcas = good.clone();
                let last = selfcas.len() - 1;
                selfcas[last].push((1, 1));
                assert_eq!(validate_layers(n, &selfcas), Err(last), "self CAS n={n}");

                // An out-of-range wire.
                let mut oob = good.clone();
                oob[0].push((0, n)); // n is one past the last index
                assert!(validate_layers(n, &oob).is_err(), "out-of-range wire n={n}");

                // Two CAS pairs sharing one endpoint in the same layer.
                let mut shared = good.clone();
                let (a, b) = shared[0][0];
                // Find an index not yet used by layer 0 to pair with `a`.
                let used: Vec<usize> = shared[0].iter().flat_map(|&(x, y)| [x, y]).collect();
                if let Some(free) = (0..n).find(|i| !used.contains(i)) {
                    shared[0].push((a, free));
                    assert_eq!(
                        validate_layers(n, &shared),
                        Err(0),
                        "shared endpoint n={n} ({a},{b})+({a},{free})"
                    );
                }
            }
        }
    }
}
