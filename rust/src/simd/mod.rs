//! Custom-SIMD instruction framework (§2 of the paper): the instruction
//! *template* abstraction ([`unit::CustomUnit`]), the four reconfigurable
//! slots ([`unit::UnitPool`]), structural network models with
//! structure-derived latencies ([`networks`]), and the standard
//! demonstration units ([`units`]): vector load/store, bitonic sort,
//! odd-even merge, and stateful prefix sum.

pub mod networks;
pub mod unit;
pub mod units;
pub mod value;

pub use unit::{CustomUnit, UnitError, UnitInputs, UnitOutput, UnitPool, VecMemOp};
pub use units::{standard_pool, MemUnit, MergeUnit, PrefixUnit, SortUnit, LOAD_PIPE_CYCLES};
pub use value::{VecVal, MAX_LANES, MAX_VLEN_BITS};
