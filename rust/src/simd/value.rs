//! Fixed-capacity vector register value.
//!
//! Vector registers are `VLEN` bits (= `VLEN/32` 32-bit lanes). The paper
//! explores VLEN from 128 to 1024 bits (Fig. 3 right), so a value fits in
//! 32 lanes; using a fixed inline array keeps the simulator's hot path
//! allocation-free.

use std::fmt;

/// Maximum supported VLEN in bits (the paper's largest explored width).
pub const MAX_VLEN_BITS: usize = 1024;
pub const MAX_LANES: usize = MAX_VLEN_BITS / 32;

/// A vector register value: `lanes` 32-bit words.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct VecVal {
    words: [u32; MAX_LANES],
    lanes: u8,
}

impl VecVal {
    /// All-zero value with `lanes` lanes (lane count = VLEN/32).
    pub fn zero(lanes: usize) -> Self {
        assert!(lanes >= 1 && lanes <= MAX_LANES, "lanes {lanes} out of range");
        Self { words: [0; MAX_LANES], lanes: lanes as u8 }
    }

    pub fn from_words(words: &[u32]) -> Self {
        let mut v = Self::zero(words.len());
        v.words[..words.len()].copy_from_slice(words);
        v
    }

    pub fn from_i32s(values: &[i32]) -> Self {
        let mut v = Self::zero(values.len());
        for (i, &x) in values.iter().enumerate() {
            v.words[i] = x as u32;
        }
        v
    }

    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes as usize
    }

    #[inline]
    pub fn words(&self) -> &[u32] {
        &self.words[..self.lanes as usize]
    }

    #[inline]
    pub fn words_mut(&mut self) -> &mut [u32] {
        &mut self.words[..self.lanes as usize]
    }

    pub fn to_i32s(&self) -> Vec<i32> {
        self.words().iter().map(|&w| w as i32).collect()
    }

    /// Bytes (little-endian lane order) — the memory image of the value.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.lanes() * 4);
        for w in self.words() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Self {
        assert_eq!(bytes.len() % 4, 0);
        let lanes = bytes.len() / 4;
        let mut v = Self::zero(lanes);
        for i in 0..lanes {
            v.words[i] = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        }
        v
    }

    /// Write this value's bytes into `buf` (must be exactly lanes*4 long).
    pub fn write_bytes(&self, buf: &mut [u8]) {
        assert_eq!(buf.len(), self.lanes() * 4);
        for (i, w) in self.words().iter().enumerate() {
            buf[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
        }
    }
}

fn fmt_lanes(v: &VecVal, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "[")?;
    for (i, w) in v.words().iter().enumerate() {
        if i > 0 {
            write!(f, ", ")?;
        }
        write!(f, "{}", *w as i32)?;
    }
    write!(f, "]")
}

impl fmt::Debug for VecVal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_lanes(self, f)
    }
}

impl fmt::Display for VecVal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_lanes(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips() {
        let v = VecVal::from_i32s(&[1, -2, 3, -4, 5, -6, 7, -8]);
        assert_eq!(v.lanes(), 8);
        assert_eq!(v.to_i32s(), vec![1, -2, 3, -4, 5, -6, 7, -8]);
        let b = v.to_bytes();
        assert_eq!(b.len(), 32);
        assert_eq!(VecVal::from_bytes(&b), v);
    }

    #[test]
    fn zero_lanes_bounds() {
        let v = VecVal::zero(4);
        assert_eq!(v.words(), &[0, 0, 0, 0]);
        let v32 = VecVal::zero(32);
        assert_eq!(v32.lanes(), 32);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn too_many_lanes_rejected() {
        VecVal::zero(33);
    }

    #[test]
    fn write_bytes_matches_to_bytes() {
        let v = VecVal::from_words(&[0xdeadbeef, 0x01020304]);
        let mut buf = [0u8; 8];
        v.write_bytes(&mut buf);
        assert_eq!(buf.to_vec(), v.to_bytes());
    }

    #[test]
    fn display_is_signed() {
        let v = VecVal::from_i32s(&[1, -1]);
        assert_eq!(format!("{v}"), "[1, -1]");
    }
}
