//! The standard unit set this repository loads into the four
//! reconfigurable slots — the paper's demonstration instructions:
//!
//! | slot | unit | funct3 | instruction | type | latency (8 lanes) |
//! |------|------|--------|-------------|------|-------------------|
//! | c0 | [`MemUnit`]    | 4 | `c0.lv`     | S′ | DL1 pipe (3) + miss |
//! | c0 | [`MemUnit`]    | 5 | `c0.sv`     | S′ | 1 |
//! | c1 | [`MergeUnit`]  | 0 | `c1.merge`  | I′ | 5 |
//! | c1 | [`MergeUnit`]  | 1 | `c1.vadd`   | I′ | 1 |
//! | c1 | [`MergeUnit`]  | 2 | `c1.vscale` | I′ | 2 |
//! | c2 | [`SortUnit`]   | 0 | `c2.sort`   | I′ | 6 |
//! | c3 | [`PrefixUnit`] | 0 | `c3.prefix` | I′ | 4 |
//! | c3 | [`PrefixUnit`] | 1 | `c3.reset`  | I′ | 1 |
//! | c3 | [`PrefixUnit`] | 2 | `c3.carry`  | I′ | 1 |
//!
//! Latencies are *derived from network structure* (`networks` module), as
//! in the Verilog templates where `cN_cycles` equals the layer count.

use super::networks::{
    bitonic_sort_network, merge_block_network, prefix_latency, prefix_sum_with_carry,
    run_network, CasLayers,
};
use super::unit::{CustomUnit, UnitError, UnitInputs, UnitOutput, VecMemOp};
use super::value::VecVal;

/// DL1 load pipeline depth on a hit (§3.2: "a latency of 3 cycles until
/// the dependent command gets executed").
pub const LOAD_PIPE_CYCLES: u64 = 3;

/// c0: vector load/store (S′-type; §2.2 "One S′ type instruction for
/// loading and storing VLEN-sized vectors is provided by default").
/// Effective address is `rs1 + rs2` (the two base sources let loops split
/// base+index across registers, §2.1).
pub struct MemUnit {
    lanes: usize,
}

impl MemUnit {
    pub fn new(lanes: usize) -> Self {
        Self { lanes }
    }
}

impl CustomUnit for MemUnit {
    fn name(&self) -> &'static str {
        "memvec"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        match funct3 {
            4 => Some("lv: load VLEN vector from rs1+rs2 into vrd1"),
            5 => Some("sv: store vrs1 to rs1+rs2"),
            _ => None,
        }
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        let addr = inp.rs1.wrapping_add(inp.rs2);
        match inp.funct3 {
            4 => Ok(UnitOutput {
                rd: None,
                vrd1: None, // filled by the core from the DL1 response
                vrd2: None,
                mem: Some(VecMemOp::Load { addr }),
                latency: LOAD_PIPE_CYCLES,
            }),
            5 => {
                if inp.vrs1.lanes() != self.lanes {
                    return Err(UnitError::BadLanes {
                        unit: "memvec",
                        expected: self.lanes,
                        got: inp.vrs1.lanes(),
                    });
                }
                Ok(UnitOutput {
                    rd: None,
                    vrd1: None,
                    vrd2: None,
                    mem: Some(VecMemOp::Store { addr, data: inp.vrs1 }),
                    latency: 1,
                })
            }
            f3 => Err(UnitError::BadFunct3 { unit: "memvec", funct3: f3 }),
        }
    }
}

/// c2: the bitonic sorting network (`c2_sort`) — sorts the VLEN/32
/// signed 32-bit lanes of `vrs1` into `vrd1`.
pub struct SortUnit {
    lanes: usize,
    network: CasLayers,
    latency: u64,
}

impl SortUnit {
    pub fn new(lanes: usize) -> Self {
        let network = bitonic_sort_network(lanes);
        let latency = network.len() as u64;
        Self { lanes, network, latency }
    }

    pub fn latency(&self) -> u64 {
        self.latency
    }
}

impl CustomUnit for SortUnit {
    fn name(&self) -> &'static str {
        "sort"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        (funct3 == 0).then_some("sort: bitonic-sort lanes of vrs1 into vrd1")
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        if inp.funct3 != 0 {
            return Err(UnitError::BadFunct3 { unit: "sort", funct3: inp.funct3 });
        }
        if inp.vrs1.lanes() != self.lanes {
            return Err(UnitError::BadLanes {
                unit: "sort",
                expected: self.lanes,
                got: inp.vrs1.lanes(),
            });
        }
        let mut vals = [0i32; crate::simd::MAX_LANES];
        for i in 0..self.lanes {
            vals[i] = inp.vrs1.words()[i] as i32;
        }
        run_network(&mut vals[..self.lanes], &self.network);
        Ok(UnitOutput::vector(VecVal::from_i32s(&vals[..self.lanes]), self.latency))
    }
}

/// c1: odd-even merge block (`c1_merge`, Fig. 5) plus two small
/// elementwise helpers (`c1.vadd`, `c1.vscale`) demonstrating that one
/// slot can host several related operations selected by funct3.
pub struct MergeUnit {
    lanes: usize,
    network: CasLayers,
    merge_latency: u64,
}

impl MergeUnit {
    pub fn new(lanes: usize) -> Self {
        let network = merge_block_network(2 * lanes);
        let merge_latency = network.len() as u64;
        Self { lanes, network, merge_latency }
    }

    pub fn merge_latency(&self) -> u64 {
        self.merge_latency
    }
}

impl CustomUnit for MergeUnit {
    fn name(&self) -> &'static str {
        "merge"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        match funct3 {
            0 => Some("merge: odd-even merge vrs1,vrs2 (sorted) -> vrd1 (low), vrd2 (high)"),
            1 => Some("vadd: elementwise vrs1 + vrs2 -> vrd1"),
            2 => Some("vscale: elementwise vrs1 * rs1 -> vrd1"),
            3 => Some("vfilt: compact lanes of vrs1 < rs1 into vrd1; rd = count"),
            _ => None,
        }
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        let check = |v: &VecVal| -> Result<(), UnitError> {
            if v.lanes() != self.lanes {
                Err(UnitError::BadLanes { unit: "merge", expected: self.lanes, got: v.lanes() })
            } else {
                Ok(())
            }
        };
        match inp.funct3 {
            0 => {
                check(&inp.vrs1)?;
                check(&inp.vrs2)?;
                // Stack buffer (max 2×32 lanes): the merge is on the
                // simulator's hottest custom-instruction path.
                let mut both = [0i32; 2 * crate::simd::MAX_LANES];
                let n = self.lanes;
                for i in 0..n {
                    both[i] = inp.vrs1.words()[i] as i32;
                    both[n + i] = inp.vrs2.words()[i] as i32;
                }
                run_network(&mut both[..2 * n], &self.network);
                let lo = VecVal::from_i32s(&both[..n]);
                let hi = VecVal::from_i32s(&both[n..2 * n]);
                Ok(UnitOutput {
                    rd: None,
                    vrd1: Some(lo),
                    vrd2: Some(hi),
                    mem: None,
                    latency: self.merge_latency,
                })
            }
            1 => {
                check(&inp.vrs1)?;
                check(&inp.vrs2)?;
                let mut out = VecVal::zero(self.lanes);
                for i in 0..self.lanes {
                    out.words_mut()[i] = inp.vrs1.words()[i].wrapping_add(inp.vrs2.words()[i]);
                }
                Ok(UnitOutput::vector(out, 1))
            }
            2 => {
                check(&inp.vrs1)?;
                let mut out = VecVal::zero(self.lanes);
                for i in 0..self.lanes {
                    out.words_mut()[i] = inp.vrs1.words()[i].wrapping_mul(inp.rs1);
                }
                Ok(UnitOutput::vector(out, 2))
            }
            3 => {
                // vfilt — the selection/compaction instruction the §4.3.2
                // database motivation calls for (Zhang & Ross [48]):
                // lanes of vrs1 strictly below the scalar threshold rs1
                // are packed densely (order-preserving) into vrd1; the
                // selected count is returned in rd. A compaction network
                // is a prefix-routed butterfly: log2(L)+2 layers.
                check(&inp.vrs1)?;
                let mut out = VecVal::zero(self.lanes);
                let mut count = 0usize;
                let threshold = inp.rs1 as i32;
                for i in 0..self.lanes {
                    let v = inp.vrs1.words()[i] as i32;
                    if v < threshold {
                        out.words_mut()[count] = v as u32;
                        count += 1;
                    }
                }
                let latency =
                    (self.lanes.trailing_zeros() as u64) + 2;
                Ok(UnitOutput {
                    rd: Some(count as u32),
                    vrd1: Some(out),
                    vrd2: None,
                    mem: None,
                    latency,
                })
            }
            f3 => Err(UnitError::BadFunct3 { unit: "merge", funct3: f3 }),
        }
    }
}

/// c3: Hillis-Steele prefix sum with an internal carry accumulator
/// (Fig. 7) — the paper's example of a *stateful* instruction (§6): the
/// carry register holds the cumulative sum of all previous batches so an
/// arbitrarily long input can be scanned in a pipelined, non-blocking way.
pub struct PrefixUnit {
    lanes: usize,
    carry: i32,
    latency: u64,
}

impl PrefixUnit {
    pub fn new(lanes: usize) -> Self {
        Self { lanes, carry: 0, latency: prefix_latency(lanes) }
    }

    pub fn latency(&self) -> u64 {
        self.latency
    }
}

impl CustomUnit for PrefixUnit {
    fn name(&self) -> &'static str {
        "prefix"
    }

    fn describe(&self, funct3: u8) -> Option<&'static str> {
        match funct3 {
            0 => Some("prefix: inclusive scan of vrs1 + carry -> vrd1; carry += total"),
            1 => Some("reset: clear the carry accumulator"),
            2 => Some("carry: read the carry accumulator into rd"),
            _ => None,
        }
    }

    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
        match inp.funct3 {
            0 => {
                if inp.vrs1.lanes() != self.lanes {
                    return Err(UnitError::BadLanes {
                        unit: "prefix",
                        expected: self.lanes,
                        got: inp.vrs1.lanes(),
                    });
                }
                let (out, new_carry) = prefix_sum_with_carry(&inp.vrs1.to_i32s(), self.carry);
                self.carry = new_carry;
                Ok(UnitOutput::vector(VecVal::from_i32s(&out), self.latency))
            }
            1 => {
                self.carry = 0;
                Ok(UnitOutput::nothing(1))
            }
            2 => Ok(UnitOutput::scalar(self.carry as u32, 1)),
            f3 => Err(UnitError::BadFunct3 { unit: "prefix", funct3: f3 }),
        }
    }

    fn reset(&mut self) {
        self.carry = 0;
    }

    fn is_stateful(&self) -> bool {
        true
    }
}

/// Build the standard pool for a given vector width.
pub fn standard_pool(vlen_bits: usize) -> super::unit::UnitPool {
    let lanes = vlen_bits / 32;
    let mut pool = super::unit::UnitPool::empty();
    pool.load(0, Box::new(MemUnit::new(lanes)));
    pool.load(1, Box::new(MergeUnit::new(lanes)));
    pool.load(2, Box::new(SortUnit::new(lanes)));
    pool.load(3, Box::new(PrefixUnit::new(lanes)));
    pool
}

/// Memory behaviour of a custom op, statically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StaticMemKind {
    /// Vector load through the DL1 pipe (writes vrd1 at the load-ready
    /// time, like a scalar load).
    Load,
    /// Vector store (completion follows the access, no register write).
    Store,
}

/// The statically-knowable timing shape of one standard-pool operation:
/// its fixed latency and which outputs it writes. This is what the
/// static cost model (`analysis::perf`) needs from a unit *without*
/// executing it; a unit test pins it against the executing units so the
/// two can never drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StaticOp {
    pub latency: u64,
    pub writes_rd: bool,
    pub writes_vrd1: bool,
    pub writes_vrd2: bool,
    pub mem: Option<StaticMemKind>,
}

/// Static shape of `(slot, funct3)` in the standard pool at `lanes`
/// lanes, or `None` where the executing pool would fault (unknown
/// funct3). Latencies are derived from the same network constructors the
/// units use, so a network change moves both in lockstep.
pub fn static_op(slot: usize, funct3: u8, lanes: usize) -> Option<StaticOp> {
    let op = |latency, writes_rd, writes_vrd1, writes_vrd2, mem| StaticOp {
        latency,
        writes_rd,
        writes_vrd1,
        writes_vrd2,
        mem,
    };
    match (slot, funct3) {
        (0, 4) => Some(op(LOAD_PIPE_CYCLES, false, true, false, Some(StaticMemKind::Load))),
        (0, 5) => Some(op(1, false, false, false, Some(StaticMemKind::Store))),
        (1, 0) => Some(op(merge_block_network(2 * lanes).len() as u64, false, true, true, None)),
        (1, 1) => Some(op(1, false, true, false, None)),
        (1, 2) => Some(op(2, false, true, false, None)),
        (1, 3) => Some(op((lanes.trailing_zeros() as u64) + 2, true, true, false, None)),
        (2, 0) => Some(op(bitonic_sort_network(lanes).len() as u64, false, true, false, None)),
        (3, 0) => Some(op(prefix_latency(lanes), false, true, false, None)),
        (3, 1) => Some(op(1, false, false, false, None)),
        (3, 2) => Some(op(1, true, false, false, None)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn inputs(funct3: u8, vrs1: VecVal, vrs2: VecVal) -> UnitInputs {
        UnitInputs { funct3, rs1: 0, rs2: 0, imm: 0, vrs1, vrs2 }
    }

    #[test]
    fn sort_unit_sorts_and_reports_paper_latency() {
        let mut u = SortUnit::new(8);
        let out = u
            .execute(&inputs(0, VecVal::from_i32s(&[5, -1, 3, 9, 0, -7, 2, 2]), VecVal::zero(8)))
            .unwrap();
        assert_eq!(out.latency, 6, "§6: 8 elements in 6 cycles");
        assert_eq!(out.vrd1.unwrap().to_i32s(), vec![-7, -1, 0, 2, 2, 3, 5, 9]);
    }

    #[test]
    fn merge_unit_merges_sorted_vectors() {
        let mut u = MergeUnit::new(8);
        let a = VecVal::from_i32s(&[1, 3, 5, 7, 9, 11, 13, 15]);
        let b = VecVal::from_i32s(&[0, 2, 4, 6, 8, 10, 12, 14]);
        let out = u.execute(&inputs(0, a, b)).unwrap();
        assert_eq!(out.latency, 5, "Fig. 6 merge stage count");
        assert_eq!(out.vrd1.unwrap().to_i32s(), vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(out.vrd2.unwrap().to_i32s(), vec![8, 9, 10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn vadd_and_vscale() {
        let mut u = MergeUnit::new(4);
        let a = VecVal::from_i32s(&[1, 2, 3, 4]);
        let b = VecVal::from_i32s(&[10, 20, 30, 40]);
        let out = u.execute(&inputs(1, a, b)).unwrap();
        assert_eq!(out.vrd1.unwrap().to_i32s(), vec![11, 22, 33, 44]);

        let mut inp = inputs(2, a, VecVal::zero(4));
        inp.rs1 = 3;
        let out = u.execute(&inp).unwrap();
        assert_eq!(out.vrd1.unwrap().to_i32s(), vec![3, 6, 9, 12]);
    }

    #[test]
    fn prefix_unit_carries_across_batches() {
        let mut u = PrefixUnit::new(8);
        let batch1 = VecVal::from_i32s(&[1, 1, 1, 1, 1, 1, 1, 1]);
        let out1 = u.execute(&inputs(0, batch1, VecVal::zero(8))).unwrap();
        assert_eq!(out1.latency, 4, "Fig. 7: log8 + carry stage");
        assert_eq!(out1.vrd1.unwrap().to_i32s(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let out2 = u.execute(&inputs(0, batch1, VecVal::zero(8))).unwrap();
        assert_eq!(out2.vrd1.unwrap().to_i32s(), vec![9, 10, 11, 12, 13, 14, 15, 16]);
        // Read and reset the carry.
        let carry = u.execute(&inputs(2, VecVal::zero(8), VecVal::zero(8))).unwrap();
        assert_eq!(carry.rd, Some(16));
        u.execute(&inputs(1, VecVal::zero(8), VecVal::zero(8))).unwrap();
        let carry = u.execute(&inputs(2, VecVal::zero(8), VecVal::zero(8))).unwrap();
        assert_eq!(carry.rd, Some(0));
    }

    #[test]
    fn mem_unit_issues_requests() {
        let mut u = MemUnit::new(8);
        let mut inp = inputs(4, VecVal::zero(8), VecVal::zero(8));
        inp.rs1 = 0x1000;
        inp.rs2 = 0x20;
        let out = u.execute(&inp).unwrap();
        assert_eq!(out.mem, Some(VecMemOp::Load { addr: 0x1020 }));
        assert_eq!(out.latency, LOAD_PIPE_CYCLES);

        let data = VecVal::from_i32s(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let mut inp = inputs(5, data, VecVal::zero(8));
        inp.rs1 = 0x2000;
        let out = u.execute(&inp).unwrap();
        assert_eq!(out.mem, Some(VecMemOp::Store { addr: 0x2000, data }));
    }

    #[test]
    fn bad_funct3_and_lanes_rejected() {
        let mut u = SortUnit::new(8);
        assert!(matches!(
            u.execute(&inputs(3, VecVal::zero(8), VecVal::zero(8))),
            Err(UnitError::BadFunct3 { .. })
        ));
        assert!(matches!(
            u.execute(&inputs(0, VecVal::zero(4), VecVal::zero(4))),
            Err(UnitError::BadLanes { .. })
        ));
    }

    #[test]
    fn standard_pool_is_fully_loaded() {
        let pool = standard_pool(256);
        for i in 0..4 {
            assert!(pool.get(i).is_some(), "slot {i}");
        }
        assert!(pool.describe().contains("c2=sort"));
    }

    /// Sorting-unit output must match `sort_unstable` on many random
    /// vectors — and sorting twice must be idempotent.
    #[test]
    fn sort_random_property() {
        crate::util::proptest::check("sort unit == std sort", 64, |rng: &mut Xoshiro256| {
            let mut u = SortUnit::new(8);
            let vals = rng.vec_i32(8);
            let mut expect = vals.clone();
            expect.sort_unstable();
            let out = u
                .execute(&UnitInputs {
                    funct3: 0,
                    rs1: 0,
                    rs2: 0,
                    imm: 0,
                    vrs1: VecVal::from_i32s(&vals),
                    vrs2: VecVal::zero(8),
                })
                .unwrap();
            let got = out.vrd1.unwrap().to_i32s();
            crate::prop_assert_eq!(got, expect);
            Ok(())
        });
    }

    /// Merging with the unit must equal a functional merge for all sorted
    /// input pairs, including duplicates and extremes.
    #[test]
    fn merge_random_property() {
        crate::util::proptest::check("merge unit == std merge", 64, |rng: &mut Xoshiro256| {
            let mut u = MergeUnit::new(8);
            let mut a = rng.vec_i32(8);
            let mut b = rng.vec_i32(8);
            if rng.below(8) == 0 {
                a = vec![i32::MIN; 8];
            }
            if rng.below(8) == 0 {
                b = vec![i32::MAX; 8];
            }
            a.sort_unstable();
            b.sort_unstable();
            let mut expect: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
            expect.sort_unstable();
            let out = u
                .execute(&UnitInputs {
                    funct3: 0,
                    rs1: 0,
                    rs2: 0,
                    imm: 0,
                    vrs1: VecVal::from_i32s(&a),
                    vrs2: VecVal::from_i32s(&b),
                })
                .unwrap();
            let mut got = out.vrd1.unwrap().to_i32s();
            got.extend(out.vrd2.unwrap().to_i32s());
            crate::prop_assert_eq!(got, expect);
            Ok(())
        });
    }

    /// `static_op` must agree with the executing pool on every
    /// (slot, funct3): same latency, same outputs written, same memory
    /// behaviour, and `None` exactly where the pool faults. This is the
    /// contract the static cost model stands on.
    #[test]
    fn static_op_table_matches_executing_units() {
        for &lanes in &[4usize, 8, 16, 32] {
            let mut pool = standard_pool(lanes * 32);
            for slot in 0..4usize {
                for funct3 in 0..8u8 {
                    let inp = UnitInputs {
                        funct3,
                        rs1: 0,
                        rs2: 0,
                        imm: 0,
                        vrs1: VecVal::zero(lanes),
                        vrs2: VecVal::zero(lanes),
                    };
                    let executed = pool.get_mut(slot).and_then(|u| u.execute(&inp));
                    match static_op(slot, funct3, lanes) {
                        None => assert!(
                            executed.is_err(),
                            "static_op says ({slot},{funct3}) faults but the pool ran it"
                        ),
                        Some(st) => {
                            let out = executed.unwrap_or_else(|e| {
                                panic!("static_op lists ({slot},{funct3}) but the pool faults: {e:?}")
                            });
                            assert_eq!(st.latency, out.latency, "latency ({slot},{funct3})");
                            assert_eq!(st.writes_rd, out.rd.is_some(), "rd ({slot},{funct3})");
                            assert_eq!(
                                st.writes_vrd1,
                                out.vrd1.is_some(),
                                "vrd1 ({slot},{funct3})"
                            );
                            assert_eq!(
                                st.writes_vrd2,
                                out.vrd2.is_some(),
                                "vrd2 ({slot},{funct3})"
                            );
                            let mem = out.mem.as_ref().map(|m| match m {
                                VecMemOp::Load { .. } => StaticMemKind::Load,
                                VecMemOp::Store { .. } => StaticMemKind::Store,
                            });
                            assert_eq!(st.mem, mem, "mem kind ({slot},{funct3})");
                        }
                    }
                }
            }
        }
    }
}
