//! The custom-instruction *template* abstraction — the Rust analogue of
//! the paper's Verilog instruction templates (§2.2, Algorithm 1).
//!
//! A hardware instruction module receives the operand data plus the
//! destination register names, and after `cN_cycles` produces results
//! with those names attached. Here a [`CustomUnit`] receives operand
//! *values* ([`UnitInputs`]) and returns result values plus its pipeline
//! `latency` ([`UnitOutput`]); the core performs register writeback and
//! scoreboard bookkeeping, exactly like the template's shift-register of
//! destination names.
//!
//! Memory-capable units (the paper's default `c0_lv`/`c0_sv`) do not
//! access memory themselves; they return a [`VecMemOp`] *request* that the
//! core routes through DL1 — in hardware, the c0 slot is the one wired to
//! the data cache.

use super::value::VecVal;

#[derive(Debug, PartialEq, Eq)]
pub enum UnitError {
    BadFunct3 { unit: &'static str, funct3: u8 },
    BadLanes { unit: &'static str, expected: usize, got: usize },
    EmptySlot(usize),
}

impl std::fmt::Display for UnitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UnitError::BadFunct3 { unit, funct3 } => {
                write!(f, "unit '{unit}' does not implement funct3={funct3}")
            }
            UnitError::BadLanes { unit, expected, got } => {
                write!(f, "unit '{unit}' requires VLEN with {expected} lanes, got {got}")
            }
            UnitError::EmptySlot(slot) => write!(f, "no unit loaded in slot c{slot}"),
        }
    }
}

impl std::error::Error for UnitError {}

/// Operand values presented to a unit on issue (the template's input
/// ports: `in_data`, `in_vdata1`, `in_vdata2`, plus S′'s second scalar).
#[derive(Debug, Clone, Copy)]
pub struct UnitInputs {
    pub funct3: u8,
    /// rs1 value (I′ and S′).
    pub rs1: u32,
    /// rs2 value (S′ only; 0 for I′).
    pub rs2: u32,
    /// S′ 1-bit immediate (0 for I′).
    pub imm: u8,
    pub vrs1: VecVal,
    pub vrs2: VecVal,
}

/// A memory request issued by a unit (serviced by the core through DL1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VecMemOp {
    /// Load a VLEN vector from `addr`; the loaded value lands in `vrd1`.
    Load { addr: u32 },
    /// Store `data` to `addr`.
    Store { addr: u32, data: VecVal },
}

/// Results of a unit invocation, available `latency` cycles after issue.
#[derive(Debug, Clone)]
pub struct UnitOutput {
    /// Scalar result for `rd` (None = rd not written).
    pub rd: Option<u32>,
    /// Vector result for `vrd1`.
    pub vrd1: Option<VecVal>,
    /// Vector result for `vrd2`.
    pub vrd2: Option<VecVal>,
    /// Memory request (load/store vector).
    pub mem: Option<VecMemOp>,
    /// Pipeline depth of this invocation (the template's `cN_cycles`).
    pub latency: u64,
}

impl UnitOutput {
    pub fn nothing(latency: u64) -> Self {
        Self { rd: None, vrd1: None, vrd2: None, mem: None, latency }
    }

    pub fn vector(vrd1: VecVal, latency: u64) -> Self {
        Self { rd: None, vrd1: Some(vrd1), vrd2: None, mem: None, latency }
    }

    pub fn scalar(rd: u32, latency: u64) -> Self {
        Self { rd: Some(rd), vrd1: None, vrd2: None, mem: None, latency }
    }
}

/// A reconfigurable execution unit loaded into one of the four custom
/// opcode slots. Implementations must be *pure per-call* except for
/// explicitly stateful units (e.g. the prefix-sum carry accumulator),
/// mirroring §6's discussion of instructions holding state.
///
/// Deliberately NOT `Send`: the HLO-backed units hold PJRT handles. A
/// `Core` is built and driven inside one thread; the sweep driver spawns
/// per-configuration threads that each construct their own core.
pub trait CustomUnit {
    /// Short name used in traces and reports (e.g. "sort").
    fn name(&self) -> &'static str;

    /// Human description of one funct3 operation, if implemented.
    fn describe(&self, funct3: u8) -> Option<&'static str>;

    /// Execute one invocation. Must not mutate architectural state other
    /// than its own internal registers.
    fn execute(&mut self, inp: &UnitInputs) -> Result<UnitOutput, UnitError>;

    /// Power-on reset (clears internal registers).
    fn reset(&mut self) {}

    /// True if the unit holds internal state across invocations (affects
    /// what the core may reorder; see §6).
    fn is_stateful(&self) -> bool {
        false
    }
}

/// The four reconfigurable slots (c0..c3). "Loading a unit" is the
/// simulator's analogue of partial reconfiguration of the instruction
/// region.
pub struct UnitPool {
    slots: [Option<Box<dyn CustomUnit>>; 4],
}

impl Default for UnitPool {
    fn default() -> Self {
        Self::empty()
    }
}

impl UnitPool {
    pub fn empty() -> Self {
        Self { slots: [None, None, None, None] }
    }

    /// Load `unit` into `slot` (replacing whatever was there).
    pub fn load(&mut self, slot: usize, unit: Box<dyn CustomUnit>) {
        assert!(slot < 4);
        self.slots[slot] = Some(unit);
    }

    pub fn unload(&mut self, slot: usize) {
        self.slots[slot] = None;
    }

    pub fn get_mut(&mut self, slot: usize) -> Result<&mut (dyn CustomUnit + 'static), UnitError> {
        match self.slots[slot].as_mut() {
            Some(b) => Ok(&mut **b),
            None => Err(UnitError::EmptySlot(slot)),
        }
    }

    pub fn get(&self, slot: usize) -> Option<&(dyn CustomUnit + 'static)> {
        self.slots[slot].as_deref()
    }

    pub fn reset_all(&mut self) {
        for s in self.slots.iter_mut().flatten() {
            s.reset();
        }
    }

    /// Inventory line for reports.
    pub fn describe(&self) -> String {
        (0..4)
            .map(|i| match self.get(i) {
                Some(u) => format!("c{i}={}", u.name()),
                None => format!("c{i}=<empty>"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy;
    impl CustomUnit for Dummy {
        fn name(&self) -> &'static str {
            "dummy"
        }
        fn describe(&self, f3: u8) -> Option<&'static str> {
            (f3 == 0).then_some("no-op")
        }
        fn execute(&mut self, _inp: &UnitInputs) -> Result<UnitOutput, UnitError> {
            Ok(UnitOutput::nothing(1))
        }
    }

    #[test]
    fn pool_load_and_dispatch() {
        let mut pool = UnitPool::empty();
        assert!(matches!(pool.get_mut(2), Err(UnitError::EmptySlot(2))));
        pool.load(2, Box::new(Dummy));
        assert_eq!(pool.get_mut(2).unwrap().name(), "dummy");
        assert!(pool.describe().contains("c2=dummy"));
        pool.unload(2);
        assert!(pool.get(2).is_none());
    }

    #[test]
    fn output_constructors() {
        let o = UnitOutput::nothing(3);
        assert_eq!(o.latency, 3);
        assert!(o.rd.is_none() && o.vrd1.is_none() && o.mem.is_none());
        let v = UnitOutput::vector(VecVal::zero(8), 6);
        assert!(v.vrd1.is_some());
        let s = UnitOutput::scalar(7, 1);
        assert_eq!(s.rd, Some(7));
    }
}
