//! # simdsoftcore
//!
//! Reproduction of "Extending the RISC-V ISA for exploring advanced
//! reconfigurable SIMD instructions" (Papaphilippou, Kelly, Luk; 2021)
//! as a cycle-level softcore simulator whose reconfigurable instruction
//! fabric is authored in JAX/Pallas and loaded as AOT-compiled XLA
//! executables via PJRT (behind the optional `pjrt` cargo feature).
//!
//! The user-facing surface is three pieces (see DESIGN.md at the repo
//! root for the walkthrough and the per-experiment index):
//!
//! - [`workloads::Workload`] — one trait over every benchmark program
//!   (build / init / verify / throughput accounting);
//! - [`machine::Machine`] — a fluent builder that turns a configuration
//!   into a ready core and runs workloads end to end;
//! - [`workloads::registry`] — the string-keyed catalogue behind the
//!   `simdsoftcore run-workload <name>` CLI subcommand and the sweeps.
//!
//! Correctness is pinned by the differential-verification subsystem
//! (DESIGN.md §9): [`ref_iss::RefIss`] is an independent,
//! architectural-only reference ISS, [`cosim::run_lockstep`] steps it
//! against the timed core instruction by instruction, and [`fuzz`]
//! generates deterministic random programs (the `fuzz` CLI subcommand)
//! across scalar and I′/S′ op mixes and machine configurations.
//!
//! Fleet-scale exploration runs through [`service`] (DESIGN.md §10): a
//! job queue over the machine grid with deterministic sharding, a
//! content-addressed result store with resumable checkpoints, and the
//! `serve` line-delimited JSON API.

pub mod analysis;
pub mod arch;
pub mod asm;
pub mod baseline;
pub mod coordinator;
pub mod core;
pub mod cosim;
pub mod fuzz;
pub mod isa;
pub mod loader;
pub mod machine;
pub mod mem;
pub mod ref_iss;
pub mod runtime;
pub mod service;
pub mod simd;
pub mod util;
pub mod workloads;
