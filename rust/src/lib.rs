//! # simdsoftcore
//!
//! Reproduction of "Extending the RISC-V ISA for exploring advanced
//! reconfigurable SIMD instructions" (Papaphilippou, Kelly, Luk; 2021)
//! as a cycle-level softcore simulator whose reconfigurable instruction
//! fabric is authored in JAX/Pallas and loaded as AOT-compiled XLA
//! executables via PJRT. See DESIGN.md for the system inventory and the
//! per-experiment index.

pub mod asm;
pub mod baseline;
pub mod coordinator;
pub mod core;
pub mod isa;
pub mod mem;
pub mod runtime;
pub mod simd;
pub mod util;
pub mod workloads;
