//! `ArchState` — the architectural-state surface shared by every
//! execution backend.
//!
//! The differential-verification subsystem (DESIGN.md §9) needs to ask
//! "what are your registers / vector registers / memory bytes" of two
//! very different machines: the cycle-level [`crate::core::Core`] and
//! the timing-free reference ISS ([`crate::ref_iss::RefIss`]). Workload
//! verification ([`crate::workloads::Workload::verify`]) and the
//! lockstep comparator ([`crate::cosim`]) are written against this
//! trait, so a workload verifies identically on either backend and a
//! new backend only has to expose its architectural state to join every
//! existing test surface.
//!
//! The contract is *architectural only*: registers, vector registers,
//! pc, instret and the memory image. Cycle counts, stall counters and
//! cache statistics are deliberately absent — they are allowed to
//! differ between backends (see the ISS architectural contract in
//! DESIGN.md §9).

use crate::isa::{Reg, VReg};
use crate::simd::VecVal;

/// Initial stack pointer for a memory of `mem_bytes`: the top of
/// memory, 16-byte aligned. Capped at `0xFFFF_FFF0` so a full 4 GiB
/// memory cannot wrap `sp` to zero through the `u32` cast (the
/// truncation bug this replaces); both execution backends use this one
/// definition so their register files stay comparable.
pub fn sp_init(mem_bytes: usize) -> u32 {
    ((mem_bytes as u64).min(0xFFFF_FFF0) as u32) & !15
}

/// Read-only view of a machine's architectural state.
///
/// For [`crate::core::Core`] the memory accessors reflect DRAM, so
/// callers must flush the cache hierarchy first (`core.mem.flush_all()`)
/// — the workload runners and the lockstep driver do this before
/// comparing. The reference ISS has no caches; its view is always
/// current.
pub trait ArchState {
    /// Base register value (`x0` reads as 0).
    fn reg(&self, r: Reg) -> u32;

    /// Vector register value (`v0` reads as the zero vector).
    fn vreg(&self, v: VReg) -> VecVal;

    /// Current program counter.
    fn pc(&self) -> u32;

    /// Retired-instruction count.
    fn instret(&self) -> u64;

    /// Whether the machine has executed its halting `ecall`.
    fn halted(&self) -> bool;

    /// Size of the flat memory image in bytes.
    fn mem_size(&self) -> usize;

    /// Borrow `len` bytes of the memory image at `addr`.
    fn mem_slice(&self, addr: u32, len: usize) -> &[u8];
}

impl ArchState for crate::core::Core {
    fn reg(&self, r: Reg) -> u32 {
        Self::reg(self, r)
    }

    fn vreg(&self, v: VReg) -> VecVal {
        Self::vreg(self, v)
    }

    fn pc(&self) -> u32 {
        Self::pc(self)
    }

    fn instret(&self) -> u64 {
        Self::instret(self)
    }

    fn halted(&self) -> bool {
        Self::halted(self)
    }

    fn mem_size(&self) -> usize {
        self.mem.dram_size()
    }

    fn mem_slice(&self, addr: u32, len: usize) -> &[u8] {
        self.mem.dram_slice(addr, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Core;
    use crate::isa::reg::*;

    #[test]
    fn sp_init_is_top_of_memory_without_wrapping() {
        assert_eq!(sp_init(64 * 1024 * 1024), 64 * 1024 * 1024);
        assert_eq!(sp_init(100), 96, "16-byte aligned");
        // The seed model computed `(size as u32) & !15`, which wraps a
        // 4 GiB memory to sp = 0; the cap keeps sp at the address-space
        // top instead.
        assert_eq!(sp_init(1 << 32), 0xFFFF_FFF0);
        assert_eq!(sp_init(usize::MAX), 0xFFFF_FFF0);
    }

    #[test]
    fn core_exposes_arch_state() {
        let mut core = Core::paper_default();
        let mut a = crate::asm::Asm::new();
        a.li(A0, 42);
        a.halt();
        let p = a.assemble().unwrap();
        core.load(&p).unwrap();
        core.run(100).unwrap();
        core.mem.flush_all();
        let arch: &dyn ArchState = &core;
        assert_eq!(arch.reg(A0), 42);
        assert_eq!(arch.reg(ZERO), 0);
        assert!(arch.halted());
        assert!(arch.instret() >= 2);
        assert_eq!(arch.mem_size(), core.mem.dram_size());
    }
}
