//! Differential-verification suite: the timed core vs the independent
//! reference ISS, in lockstep, over both hand-written programs and the
//! riscv-dv-style random program generator.
//!
//! This is the tier-1 slice of the fuzz campaign (a few dozen seeds so
//! `cargo test` stays fast); CI additionally runs the 500-seed
//! `fuzz-smoke` job and the acceptance target is
//! `simdsoftcore fuzz --seeds 2000`.

use simdsoftcore::arch::ArchState;
use simdsoftcore::coordinator::sweep::MachinePoint;
use simdsoftcore::cosim::{run_lockstep, LockstepOutcome};
use simdsoftcore::fuzz::{self, FuzzConfig, OpWeights};
use simdsoftcore::machine::{Backend, Machine};
use simdsoftcore::ref_iss::RefIss;
use simdsoftcore::workloads::{lookup, Scenario, Variant};

/// Every workload program (at smoke size) retires identically on the
/// timed core and the ISS when run in lockstep — a denser check than
/// end-state comparison because it pins each intermediate register
/// state too.
#[test]
fn workload_programs_agree_in_lockstep() {
    for (name, variant) in [
        ("memcpy", Variant::Vector),
        ("memcpy", Variant::Scalar),
        ("sort", Variant::Vector),
        ("prefix", Variant::Vector),
        ("filter", Variant::Vector),
        ("dhrystone", Variant::Scalar),
    ] {
        let mut w = lookup(name).expect("registered workload");
        let sc = Scenario::new(variant, w.smoke_size());
        let machine = Machine::paper_default().dram_bytes(64 * 1024 * 1024);
        let mut core = machine.build();
        let mut iss = machine.build_iss();
        let prog = w.build(&Scenario { vlen_bits: 256, ..sc });
        core.load(&prog).unwrap();
        iss.load(&prog).unwrap();
        for (addr, bytes) in w.init_image() {
            core.mem.host_write(*addr, bytes);
            iss.host_write(*addr, bytes).unwrap();
        }
        let r = run_lockstep(&mut core, &mut iss, 50_000_000)
            .unwrap_or_else(|d| panic!("{name} {variant} diverged:\n{d}"));
        assert_eq!(r.outcome, LockstepOutcome::Halted, "{name} {variant}");
        assert!(w.verify(&iss).is_ok(), "{name} {variant}: ISS-side verify");
        assert!(w.verify(&core).is_ok(), "{name} {variant}: core-side verify");
    }
}

/// The tier-1 fuzz slice: 24 seeds x (default + stressed memory) across
/// the rotating balanced/scalar/vector op-mix presets.
#[test]
fn random_programs_agree_on_default_and_stressed_machines() {
    let cfg = FuzzConfig { seeds: 24, base_seed: 1, ops: 250, ..Default::default() };
    assert_eq!(cfg.points.len(), 2, "default grid = paper machine + stressed memory");
    assert_eq!(cfg.points[1], fuzz::stressed_point());
    let summary = fuzz::run_campaign(&cfg);
    for f in &summary.failures {
        eprintln!(
            "== seed {} ({}, {:?}) ==\n{}\n{}",
            f.seed, f.weights_name, f.point, f.report, f.listing
        );
    }
    assert!(summary.ok(), "{} divergences (see stderr)", summary.failures.len());
    assert_eq!(summary.cases, 48);
    assert_eq!(summary.faulted, 0, "generated programs must never fault");
}

/// Fuzzing across the VLEN axis (the sweep integration the coordinator
/// exposes to the CLI): program generation adapts to the lane count and
/// every width agrees.
#[test]
fn random_programs_agree_across_vlen_sweep() {
    let points: Vec<MachinePoint> = [128usize, 512]
        .iter()
        .map(|&vlen| MachinePoint { vlen, ..Default::default() })
        .collect();
    for mp in &points {
        mp.validate().expect("sweepable point");
    }
    let cfg = FuzzConfig { seeds: 6, base_seed: 77, ops: 200, points, ..Default::default() };
    let summary = fuzz::run_campaign(&cfg);
    assert!(summary.ok(), "{} divergences across VLEN sweep", summary.failures.len());
    assert_eq!(summary.cases, 12);
}

/// Wild jumps fault **identically** on both backends — the timed core
/// used to panic (decode-cache truncation / misaligned fetch across an
/// IL1 block edge) where the ISS reported or silently decoded raw
/// bytes. Out-of-DRAM targets are a fetch fault, non-word-aligned
/// targets a misaligned-fetch fault, and lockstep treats the identical
/// pair as agreement.
#[test]
fn wild_jumps_fault_identically_on_both_backends() {
    use simdsoftcore::asm::Asm;
    use simdsoftcore::isa::reg::{A0, RA};

    let run_pair = |build: &dyn Fn(&mut Asm)| {
        let mut a = Asm::new();
        build(&mut a);
        let prog = a.assemble().expect("wild-jump program assembles");
        let machine = Machine::paper_default().dram_bytes(fuzz::FUZZ_DRAM_BYTES);
        let mut core = machine.build();
        let mut iss = RefIss::new(256, core.mem.dram_size());
        core.load(&prog).unwrap();
        iss.load(&prog).unwrap();
        run_lockstep(&mut core, &mut iss, 1000).expect("identical faults are agreement")
    };

    let r = run_pair(&|a| {
        a.li(A0, 0xF000_0000u32 as i64);
        a.jalr(RA, A0, 0);
        a.halt();
    });
    match r.outcome {
        LockstepOutcome::Faulted(ref what) => {
            assert!(what.starts_with("fetchfault@"), "{what}")
        }
        other => panic!("expected identical fetch fault, got {other:?}"),
    }

    let r = run_pair(&|a| {
        a.auipc(A0, 0);
        a.jalr(RA, A0, 6); // target % 4 == 2
        a.halt();
    });
    match r.outcome {
        LockstepOutcome::Faulted(ref what) => {
            assert!(what.starts_with("fetchmisaligned@"), "{what}")
        }
        other => panic!("expected identical misaligned fault, got {other:?}"),
    }
}

/// The wild-jump fuzz class (tier-1 slice of the 500-seed CI job): with
/// `wildjump` weighted in, every case must end in a halt or an
/// identical fetch fault — never a divergence, data fault, watchdog or
/// panic — on the default and stressed (dual-issue) machines.
#[test]
fn wildjump_fuzz_slice_runs_clean() {
    let cfg = FuzzConfig {
        seeds: 24,
        base_seed: 1,
        ops: 200,
        weights: Some(OpWeights::wild()),
        ..Default::default()
    };
    let summary = fuzz::run_campaign(&cfg);
    for f in &summary.failures {
        eprintln!(
            "== seed {} ({}, {:?}) ==\n{}\n{}",
            f.seed, f.weights_name, f.point, f.report, f.listing
        );
    }
    assert!(summary.ok(), "{} wild-jump failures (see stderr)", summary.failures.len());
    assert_eq!(summary.cases, 48);
}

/// A seeded divergence is actually caught and usefully reported: plant
/// a wrong value in the ISS register file and check the report carries
/// the register delta and a disassembly context window.
#[test]
fn planted_divergence_produces_actionable_report() {
    let prog = fuzz::generate(3, 120, &OpWeights::scalar(), 256);
    let machine = Machine::paper_default().dram_bytes(fuzz::FUZZ_DRAM_BYTES);
    let mut core = machine.build();
    let mut iss = RefIss::new(256, core.mem.dram_size());
    core.load(&prog).unwrap();
    iss.load(&prog).unwrap();
    // Corrupt a pool register the generator writes early and often.
    iss.force_reg(simdsoftcore::isa::reg::A0, 0x1234_5678);
    let d = run_lockstep(&mut core, &mut iss, 100_000).expect_err("must diverge");
    let text = d.to_string();
    assert!(text.contains("core=") && text.contains("iss="), "{text}");
    assert!(text.contains("context"), "report carries a context window: {text}");
}

/// The ISS functional backend executes the entire registry with the
/// same verify outcome and instruction count as the timed core — the
/// `Backend::RefIss` face of the same differential invariant.
#[test]
fn ref_iss_backend_matches_timed_core_across_registry() {
    for entry in simdsoftcore::workloads::registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            let mut w_timed = entry.make();
            let mut w_iss = entry.make();
            let sc = Scenario::new(variant, probe.smoke_size());
            let timed =
                Machine::paper_default().run(&mut *w_timed, &sc).expect("timed run");
            let iss = Machine::paper_default()
                .backend(Backend::RefIss)
                .run(&mut *w_iss, &sc)
                .expect("iss run");
            assert_eq!(timed.verified, Some(true), "{} {variant} timed", entry.name);
            assert_eq!(iss.verified, Some(true), "{} {variant} iss", entry.name);
            assert_eq!(
                timed.throughput.instret, iss.throughput.instret,
                "{} {variant}: backends retire different instruction counts",
                entry.name
            );
        }
    }
}
