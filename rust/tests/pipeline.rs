//! Pipeline-model suite: `issue_width` is a **timing-only** axis.
//!
//! Three invariants:
//! - `issue_width = 1` (the default) IS the seed model — same cycles,
//!   same counters, same verify outcome for every registry workload;
//! - wider issue changes cycle counts only: architectural results
//!   (instret, registers, memory, verify) are identical at every width,
//!   pinned by workload runs and a differential fuzz slice across the
//!   `issue-width` sweep axis;
//! - the calibrated effect: dual issue cuts >= 15% of cycles on the
//!   cpubench and scalar STREAM-copy kernels (the `pipe-sweep` curve CI
//!   captures as `BENCH_pipeline.json`).

use simdsoftcore::coordinator::sweep::MachinePoint;
use simdsoftcore::fuzz::{self, FuzzConfig};
use simdsoftcore::machine::Machine;
use simdsoftcore::workloads::{lookup, registry, Scenario, Variant};

#[test]
fn width_one_is_identical_to_the_default_machine_across_registry() {
    for entry in registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            let sc = Scenario::new(variant, probe.smoke_size());
            let mut w_default = entry.make();
            let mut w_one = entry.make();
            let base = Machine::paper_default().run(&mut *w_default, &sc).expect("default run");
            let one = Machine::paper_default()
                .issue_width(1)
                .run(&mut *w_one, &sc)
                .expect("explicit width-1 run");
            assert_eq!(
                base.throughput.cycles, one.throughput.cycles,
                "{} {variant}: issue_width(1) must be cycle-identical to the default",
                entry.name
            );
            assert_eq!(base.throughput.instret, one.throughput.instret, "{}", entry.name);
            assert_eq!(base.counters, one.counters, "{} {variant}", entry.name);
            assert_eq!(one.counters.dual_issue_pairs, 0, "{}", entry.name);
            assert_eq!(one.counters.issue_slots_wasted, 0, "{}", entry.name);
            assert_eq!(one.verified, Some(true), "{} {variant}", entry.name);
        }
    }
}

#[test]
fn wider_issue_is_architecturally_identical_and_not_slower() {
    for (name, variant) in [
        ("dhrystone", Variant::Scalar),
        ("coremark", Variant::Scalar),
        ("stream-copy", Variant::Scalar),
        ("memcpy", Variant::Vector),
        ("sort", Variant::Vector),
        ("prefix", Variant::Vector),
    ] {
        let probe = lookup(name).expect("registered workload");
        let sc = Scenario::new(variant, probe.smoke_size());
        let runs: Vec<_> = [1usize, 2, 4]
            .iter()
            .map(|&width| {
                let mut w = lookup(name).expect("registered workload");
                Machine::paper_default()
                    .issue_width(width)
                    .run(&mut *w, &sc)
                    .unwrap_or_else(|e| panic!("{name} at width {width}: {e}"))
            })
            .collect();
        for (r, width) in runs.iter().zip([1u64, 2, 4]) {
            assert_eq!(r.verified, Some(true), "{name} width {width}");
            assert_eq!(
                r.throughput.instret, runs[0].throughput.instret,
                "{name} width {width}: instruction count must not depend on issue width"
            );
        }
        assert!(
            runs[1].throughput.cycles <= runs[0].throughput.cycles,
            "{name}: width 2 slower than width 1 ({} vs {})",
            runs[1].throughput.cycles,
            runs[0].throughput.cycles
        );
        assert!(
            runs[2].throughput.cycles <= runs[0].throughput.cycles,
            "{name}: width 4 slower than width 1 ({} vs {})",
            runs[2].throughput.cycles,
            runs[0].throughput.cycles
        );
        assert_eq!(runs[0].counters.dual_issue_pairs, 0, "{name}");
        assert!(runs[1].counters.dual_issue_pairs > 0, "{name}: width 2 never paired");
    }
}

/// The acceptance band: dual issue saves >= 15% of cycles on cpubench
/// (dhrystone-like) and scalar STREAM copy at default experiment sizes.
/// (The full curve, including coremark and the vector kernels, is the
/// `pipe-sweep` experiment.)
#[test]
fn dual_issue_cuts_at_least_fifteen_percent_on_cpubench_and_stream_copy() {
    for (name, size) in [("dhrystone", 300usize), ("stream-copy", 256 * 1024)] {
        let sc = Scenario::new(Variant::Scalar, size);
        let mut w1 = lookup(name).expect("registered workload");
        let mut w2 = lookup(name).expect("registered workload");
        let r1 = Machine::paper_default().run(&mut *w1, &sc).expect("width-1 run");
        let r2 = Machine::paper_default().issue_width(2).run(&mut *w2, &sc).expect("width-2 run");
        assert_eq!(r2.verified, Some(true), "{name}");
        let gain = 1.0 - r2.throughput.cycles as f64 / r1.throughput.cycles as f64;
        assert!(
            gain >= 0.15,
            "{name}: dual issue saved only {:.1}% ({} vs {} cycles)",
            gain * 100.0,
            r2.throughput.cycles,
            r1.throughput.cycles
        );
        // coremark must improve too, but its pointer-chasing list walk
        // bounds the win; it is reported, not banded, in pipe-sweep.
    }
    let sc = Scenario::new(Variant::Scalar, 100);
    let r1 = Machine::paper_default().run(&mut *lookup("coremark").unwrap(), &sc).unwrap();
    let r2 = Machine::paper_default()
        .issue_width(2)
        .run(&mut *lookup("coremark").unwrap(), &sc)
        .unwrap();
    assert!(
        r2.throughput.cycles < r1.throughput.cycles,
        "coremark: width 2 must save cycles ({} vs {})",
        r2.throughput.cycles,
        r1.throughput.cycles
    );
}

/// Differential fuzz slice across the `issue-width` axis: 16 seeds x
/// widths {1, 2, 4} = 48 lockstep cases, every one architecturally
/// identical to the reference ISS (the ISS has no pipeline at all, so
/// agreement proves the width is timing-only).
#[test]
fn fuzz_slice_agrees_across_issue_width_sweep() {
    let points: Vec<MachinePoint> = [1usize, 2, 4]
        .iter()
        .map(|&issue_width| MachinePoint { issue_width, ..Default::default() })
        .collect();
    for mp in &points {
        mp.validate().expect("sweepable point");
    }
    let cfg = FuzzConfig { seeds: 16, base_seed: 1, ops: 250, points, ..Default::default() };
    let summary = fuzz::run_campaign(&cfg);
    for f in &summary.failures {
        eprintln!(
            "== seed {} ({}, {:?}) ==\n{}\n{}",
            f.seed, f.weights_name, f.point, f.report, f.listing
        );
    }
    assert!(summary.ok(), "{} divergences across issue widths", summary.failures.len());
    assert_eq!(summary.cases, 48);
}
