//! ELF loader integration tests (DESIGN.md §13): the
//! `load_program(write_elf(p)) == p` round-trip over the whole workload
//! registry, behavioural identity of a loaded image on both backends,
//! and the malformed-image rejection corpus.

use simdsoftcore::asm::Asm;
use simdsoftcore::core::{Core, CoreConfig};
use simdsoftcore::cosim::{run_lockstep, LockstepOutcome};
use simdsoftcore::isa::reg::*;
use simdsoftcore::loader::{self, write::write_elf, LoaderError};
use simdsoftcore::mem::MemConfig;
use simdsoftcore::ref_iss::RefIss;
use simdsoftcore::workloads::{lookup, registry, Scenario};

/// Every registry program survives the ELF round trip with a bit-
/// identical memory image: same text words at the same base, same data
/// bytes at the same base, same entry, every symbol preserved.
#[test]
fn every_registry_program_round_trips_bit_identically() {
    for entry in registry() {
        let mut w = entry.make();
        let variants = w.variants().to_vec();
        for variant in variants {
            let sc = Scenario::new(variant, w.smoke_size());
            let p = w.build(&sc);
            let elf = write_elf(&p);
            let back = loader::load_program(&elf)
                .unwrap_or_else(|e| panic!("{} [{variant}]: {e}", entry.name));
            assert_eq!(back.text_base, p.text_base, "{} [{variant}]", entry.name);
            assert_eq!(back.text, p.text, "{} [{variant}]", entry.name);
            assert_eq!(back.entry, p.entry, "{} [{variant}]", entry.name);
            if !p.data.is_empty() {
                assert_eq!(back.data_base, p.data_base, "{} [{variant}]", entry.name);
                assert_eq!(back.data, p.data, "{} [{variant}]", entry.name);
            }
            for (name, &addr) in &p.symbols {
                assert_eq!(
                    back.symbols.get(name),
                    Some(&addr),
                    "{} [{variant}]: symbol {name}",
                    entry.name
                );
            }
        }
    }
}

/// A program that went through the ELF round trip runs identically on
/// the timed core and the reference ISS (lockstep, zero divergences).
#[test]
fn a_loaded_elf_runs_in_lockstep_on_both_backends() {
    let mut w = lookup("memcpy").expect("memcpy is a registry workload");
    let variant = w.variants()[0];
    let sc = Scenario::new(variant, w.smoke_size());
    let p = w.build(&sc);
    let p = loader::load_program(&write_elf(&p)).expect("round trip");

    let mut core = Core::new(CoreConfig::paper_default(), MemConfig::paper_default());
    core.load(&p).expect("core load");
    let mut iss = RefIss::paper_default(core.mem.dram_size());
    iss.load(&p).expect("iss load");
    let r = run_lockstep(&mut core, &mut iss, 50_000_000).expect("no divergence");
    assert_eq!(r.outcome, LockstepOutcome::Halted);
    assert!(r.instret > 0);
}

/// A small valid image for the rejection corpus to mutate.
fn valid_elf() -> Vec<u8> {
    let mut a = Asm::new();
    a.words("tohost", &[0]);
    a.li(A0, 1);
    a.halt();
    write_elf(&a.assemble().unwrap())
}

fn put_u16(b: &mut [u8], off: usize, v: u16) {
    b[off..off + 2].copy_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut [u8], off: usize, v: u32) {
    b[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

/// Each class of malformed image draws its specific [`LoaderError`] —
/// never a panic, never a silently wrong [`simdsoftcore::asm::Program`].
#[test]
fn malformed_images_are_rejected_with_specific_errors() {
    let good = valid_elf();
    loader::load_program(&good).expect("the unmutated image is valid");

    // Offsets per the ELF32 spec: e_entry@24, phdrs at phoff=52 with
    // p_offset@+4, p_vaddr@+8, p_filesz@+16, p_memsz@+20.
    let cases: Vec<(&str, Box<dyn Fn(&mut Vec<u8>)>, fn(&LoaderError) -> bool)> = vec![
        (
            "truncated header",
            Box::new(|b: &mut Vec<u8>| b.truncate(40)),
            |e| matches!(e, LoaderError::TruncatedHeader { len: 40 }),
        ),
        (
            "bad magic",
            Box::new(|b: &mut Vec<u8>| b[0] = 0x7e),
            |e| matches!(e, LoaderError::BadMagic(_)),
        ),
        (
            "ELFCLASS64",
            Box::new(|b: &mut Vec<u8>| b[4] = 2),
            |e| matches!(e, LoaderError::NotElf32(2)),
        ),
        (
            "big-endian",
            Box::new(|b: &mut Vec<u8>| b[5] = 2),
            |e| matches!(e, LoaderError::NotLittleEndian(2)),
        ),
        (
            "relocatable object",
            Box::new(|b: &mut Vec<u8>| put_u16(b, 16, 1)),
            |e| matches!(e, LoaderError::NotExecutable(1)),
        ),
        (
            "x86-64 machine",
            Box::new(|b: &mut Vec<u8>| put_u16(b, 18, 62)),
            |e| matches!(e, LoaderError::WrongMachine(62)),
        ),
        (
            "bad phentsize",
            Box::new(|b: &mut Vec<u8>| put_u16(b, 42, 33)),
            |e| matches!(e, LoaderError::BadPhentSize(33)),
        ),
        (
            "phnum past end of file",
            Box::new(|b: &mut Vec<u8>| put_u16(b, 44, 400)),
            |e| matches!(e, LoaderError::TruncatedProgramHeaders { .. }),
        ),
        (
            "segment crossing the 4 GiB boundary",
            Box::new(|b: &mut Vec<u8>| put_u32(b, 52 + 8, 0xFFFF_FFFC)),
            |e| matches!(e, LoaderError::SegmentOutOfAddressSpace { .. }),
        ),
        (
            "filesz exceeding memsz",
            Box::new(|b: &mut Vec<u8>| {
                let memsz = u32::from_le_bytes(b[52 + 20..52 + 24].try_into().unwrap());
                put_u32(b, 52 + 16, memsz + 1);
            }),
            |e| matches!(e, LoaderError::FileszExceedsMemsz { .. }),
        ),
        (
            "segment data past end of file",
            Box::new(|b: &mut Vec<u8>| put_u32(b, 52 + 4, 0x7FFF_0000)),
            |e| matches!(e, LoaderError::TruncatedSegment { .. }),
        ),
        (
            "misaligned entry",
            Box::new(|b: &mut Vec<u8>| {
                let entry = u32::from_le_bytes(b[24..28].try_into().unwrap());
                put_u32(b, 24, entry + 2);
            }),
            |e| matches!(e, LoaderError::MisalignedEntry { .. }),
        ),
        (
            "entry outside every executable segment",
            Box::new(|b: &mut Vec<u8>| {
                // Point the entry at the (non-executable) data segment.
                let data_vaddr = u32::from_le_bytes(b[52 + 32 + 8..52 + 32 + 12].try_into().unwrap());
                put_u32(b, 24, data_vaddr);
            }),
            |e| matches!(e, LoaderError::EntryOutsideText { .. }),
        ),
        (
            "overlapping segments",
            Box::new(|b: &mut Vec<u8>| {
                let text_vaddr = u32::from_le_bytes(b[52 + 8..52 + 12].try_into().unwrap());
                put_u32(b, 52 + 32 + 8, text_vaddr);
            }),
            |e| matches!(e, LoaderError::OverlappingSegments { .. }),
        ),
    ];

    for (what, mutate, expected) in cases {
        let mut bytes = good.clone();
        mutate(&mut bytes);
        match loader::load_program(&bytes) {
            Err(e) => assert!(expected(&e), "{what}: unexpected error {e:?}"),
            Ok(_) => panic!("{what}: malformed image was accepted"),
        }
    }
}
