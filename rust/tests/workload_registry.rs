//! Registry-wide property tests: every registered workload must build,
//! run at a small size on the paper-default machine, and pass its own
//! verification for every variant it declares — and where a workload has
//! both scalar and vector implementations, they must agree on results.
//!
//! This is the contract that keeps `run-workload <name>` and the sweep
//! drivers trustworthy as new scenarios are registered.

use simdsoftcore::machine::Machine;
use simdsoftcore::workloads::{registry, run_on, Scenario, Variant};

#[test]
fn registry_names_are_unique_and_self_describing() {
    let entries = registry();
    assert!(entries.len() >= 10, "expected the full workload catalogue");
    let mut names: Vec<&str> = entries.iter().map(|e| e.name).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), entries.len(), "duplicate registry names");
    for e in &entries {
        let w = e.make();
        assert_eq!(w.name(), e.name);
        assert!(!w.description().is_empty(), "{}: empty description", e.name);
        assert!(!w.variants().is_empty(), "{}: no variants", e.name);
        assert!(w.smoke_size() > 0 && w.default_size() >= w.smoke_size(), "{}", e.name);
    }
}

/// Every (workload, variant) point builds, runs and verifies on the
/// paper-default machine at its smoke size.
#[test]
fn every_workload_runs_and_verifies_on_the_paper_default_machine() {
    let machine = Machine::paper_default();
    for entry in registry() {
        let variants = entry.make().variants().to_vec();
        for variant in variants {
            let mut w = entry.make();
            let sc = Scenario::new(variant, w.smoke_size());
            let r = machine
                .run(&mut *w, &sc)
                .unwrap_or_else(|e| panic!("{} [{variant}]: {e}", entry.name));
            assert_eq!(
                r.verified,
                Some(true),
                "{} [{variant}]: {:?}",
                entry.name,
                r.verify_error
            );
            assert!(r.throughput.cycles > 0 && r.throughput.instret > 0);
            assert_eq!(r.workload, entry.name);
        }
    }
}

/// Scalar and vector variants of one workload must produce identical
/// result data (the custom units accelerate, never change, semantics).
#[test]
fn scalar_and_vector_variants_agree_on_results() {
    for entry in registry() {
        let variants = entry.make().variants().to_vec();
        if variants.len() < 2 {
            continue;
        }
        let mut results = Vec::new();
        for variant in variants {
            let mut w = entry.make();
            let sc = Scenario::new(variant, w.smoke_size());
            let mut core = Machine::paper_default().build();
            let r = run_on(&mut *w, &mut core, &sc)
                .unwrap_or_else(|e| panic!("{} [{variant}]: {e}", entry.name));
            assert_eq!(r.verified, Some(true), "{} [{variant}]", entry.name);
            let data = w.result_data(&core);
            assert!(!data.is_empty(), "{} [{variant}]: no result data", entry.name);
            results.push((variant, data));
        }
        let (v0, d0) = &results[0];
        for (v, d) in &results[1..] {
            assert_eq!(d, d0, "{}: {v} disagrees with {v0}", entry.name);
        }
    }
}

/// `required_units` is honest: stripping a required unit makes the
/// variant fail to launch, while unaffected variants still run.
#[test]
fn required_units_gate_execution() {
    for entry in registry() {
        let variants = entry.make().variants().to_vec();
        for variant in variants {
            let probe = entry.make();
            let slots = probe.required_units(variant).to_vec();
            for slot in slots {
                let machine = Machine::paper_default().without_unit(slot);
                let mut w = entry.make();
                let sc = Scenario::new(variant, w.smoke_size());
                let err = machine.run(&mut *w, &sc).err().unwrap_or_else(|| {
                    panic!("{} [{variant}] ran without required unit c{slot}", entry.name)
                });
                let msg = err.to_string();
                assert!(msg.contains(&format!("c{slot}")), "{}: {msg}", entry.name);
            }
        }
    }
}

/// The vector workloads hold up across the paper's explored widths, not
/// just the Table-1 default.
#[test]
fn vector_variants_verify_across_vlens() {
    for vlen in [128usize, 512] {
        let machine = Machine::for_vlen(vlen);
        for entry in registry() {
            let mut w = entry.make();
            if !w.variants().contains(&Variant::Vector) {
                continue;
            }
            let sc = Scenario::new(Variant::Vector, w.smoke_size());
            let r = machine
                .run(&mut *w, &sc)
                .unwrap_or_else(|e| panic!("{} @vlen {vlen}: {e}", entry.name));
            assert_eq!(r.verified, Some(true), "{} @vlen {vlen}", entry.name);
        }
    }
}
