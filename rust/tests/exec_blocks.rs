//! Execution-engine identity suite: the ISS's cached basic-block
//! engine must be architecturally indistinguishable from per-instruction
//! dispatch and from the cacheless decode-fresh oracle — across the
//! whole workload registry, the fuzz corpus, and adversarial
//! self-modifying-code sequences that attack the block cache's
//! invalidation contract (DESIGN.md §11). The timed core's side of the
//! same contract (store-over-text invalidates its predecoded text and
//! fetch line buffer) is pinned here too.

use simdsoftcore::arch::ArchState;
use simdsoftcore::asm::Asm;
use simdsoftcore::fuzz::{self, OpWeights};
use simdsoftcore::isa::reg::*;
use simdsoftcore::isa::{encode, Instr, Reg, VReg};
use simdsoftcore::machine::Machine;
use simdsoftcore::ref_iss::{ExecEngine, RefIss};
use simdsoftcore::workloads::{registry, run_on_iss_engine, Scenario};

const ENGINES: [ExecEngine; 3] =
    [ExecEngine::Blocks, ExecEngine::PerInstr, ExecEngine::Uncached];

/// Full architectural state of a finished (or faulted) ISS run, for
/// exact cross-engine comparison — registers, vector registers, pc,
/// instret, halt flag and the error rendering if any.
fn arch_fingerprint(iss: &RefIss, err: Option<String>) -> (Vec<u32>, Vec<Vec<i32>>, u32, u64, bool, Option<String>) {
    let regs = (0..32).map(|n| iss.reg(Reg(n))).collect();
    let vregs = (0..8).map(|n| iss.vreg(VReg(n)).to_i32s()).collect();
    (regs, vregs, iss.pc(), iss.instret(), iss.halted(), err)
}

/// Every registry workload, on every variant, produces bit-identical
/// results (verify outcome, retired instructions, final registers and
/// the complete memory image) on all three engines.
#[test]
fn engines_agree_on_every_registry_workload() {
    for entry in registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            let sc = Scenario::new(variant, probe.smoke_size());
            let machine = Machine::paper_default().dram_bytes(64 * 1024 * 1024);
            let mut runs = Vec::new();
            for engine in ENGINES {
                let mut w = entry.make();
                let mut iss = machine.build_iss();
                let report = run_on_iss_engine(&mut *w, &mut iss, &sc, engine)
                    .unwrap_or_else(|e| panic!("{} {variant} on {engine:?}: {e}", entry.name));
                assert_eq!(
                    report.verified,
                    Some(true),
                    "{} {variant} fails verification on {engine:?}",
                    entry.name
                );
                runs.push((engine, report.throughput.instret, iss));
            }
            let (_, instret0, iss0) = &runs[0];
            for (engine, instret, iss) in &runs[1..] {
                assert_eq!(
                    instret, instret0,
                    "{} {variant}: {engine:?} retires a different instruction count",
                    entry.name
                );
                assert_eq!(
                    arch_fingerprint(iss, None),
                    arch_fingerprint(iss0, None),
                    "{} {variant}: {engine:?} architectural state differs",
                    entry.name
                );
                assert!(
                    iss.mem_slice(0, iss.mem_size()) == iss0.mem_slice(0, iss0.mem_size()),
                    "{} {variant}: {engine:?} memory image differs",
                    entry.name
                );
            }
        }
    }
}

/// Run one generated program on a fresh ISS with the given engine and
/// return its full fingerprint plus memory image.
fn run_fuzz_program(
    seed: u64,
    ops: usize,
    w: &OpWeights,
    engine: ExecEngine,
) -> ((Vec<u32>, Vec<Vec<i32>>, u32, u64, bool, Option<String>), Vec<u8>) {
    let prog = fuzz::generate(seed, ops, w, 256);
    let mut iss = RefIss::new(256, fuzz::FUZZ_DRAM_BYTES);
    iss.load(&prog).expect("fuzz image fits");
    let err = iss.run_with(fuzz::max_instrs_for(ops), engine).err().map(|e| e.to_string());
    let mem = iss.mem_slice(0, iss.mem_size()).to_vec();
    (arch_fingerprint(&iss, err), mem)
}

/// The fuzz corpus (rotating balanced/scalar/vector presets, the same
/// generator as the tier-1 cosim slice) is engine-invariant: registers,
/// vector registers, pc, instret, halt/fault identity and the entire
/// memory image all match across the three engines.
#[test]
fn engines_agree_on_fuzz_corpus() {
    for seed in 0..12u64 {
        let (name, w) = OpWeights::preset_for_seed(seed);
        let baseline = run_fuzz_program(seed, 250, &w, ExecEngine::Uncached);
        for engine in [ExecEngine::Blocks, ExecEngine::PerInstr] {
            let got = run_fuzz_program(seed, 250, &w, engine);
            assert_eq!(
                got.0, baseline.0,
                "seed {seed} ({name}): {engine:?} state differs from the uncached oracle"
            );
            assert!(
                got.1 == baseline.1,
                "seed {seed} ({name}): {engine:?} memory image differs from the uncached oracle"
            );
        }
    }
}

/// The block-cache invalidation property test: programs heavy in
/// self-modifying stores (random store-over-text sequences, both over
/// already-executed and not-yet-executed words) must leave the block
/// engine bit-identical to the decode-fresh oracle, which has no cache
/// to go stale.
#[test]
fn block_cache_invalidation_matches_uncached_oracle_under_smc() {
    let w = OpWeights { smc: 4, ..OpWeights::balanced() };
    for seed in 5100..5124u64 {
        let baseline = run_fuzz_program(seed, 200, &w, ExecEngine::Uncached);
        let blocks = run_fuzz_program(seed, 200, &w, ExecEngine::Blocks);
        assert_eq!(
            blocks.0, baseline.0,
            "seed {seed}: stale block survived a store over text"
        );
        assert!(blocks.1 == baseline.1, "seed {seed}: memory image differs");
    }
}

/// Assemble the backward-patch SMC regression program: a two-iteration
/// loop whose first instruction (`addi a0, a0, 1`) is overwritten with
/// `addi a0, a0, 100` after iteration one. A backend with a stale
/// decode cache computes 2; correct invalidation computes 101.
fn backward_patch_program() -> simdsoftcore::asm::Program {
    let patch = encode(&Instr::Addi { rd: A0, rs1: A0, imm: 100 }).unwrap();
    let mut a = Asm::new();
    a.li(A0, 0);
    a.li(S10, 2);
    a.li(T1, patch as i64);
    let head = a.new_label("head");
    a.bind(head);
    a.addi(A0, A0, 1);
    a.la(T0, head);
    a.sw(T1, 0, T0);
    a.addi(S10, S10, -1);
    a.bnez(S10, head);
    a.halt();
    a.assemble().unwrap()
}

/// The timed core's half of the stale-`decoded`-cache bugfix: a store
/// over an already-executed instruction must invalidate the core's
/// predecoded text AND its fetch line buffer, so the refetch decodes
/// the patched word. (The ISS half lives in `src/ref_iss` unit tests.)
#[test]
fn timed_core_reexecutes_patched_instruction_after_text_store() {
    for issue_width in [1usize, 2] {
        let mut core = Machine::paper_default()
            .dram_bytes(fuzz::FUZZ_DRAM_BYTES)
            .issue_width(issue_width)
            .build();
        core.load(&backward_patch_program()).unwrap();
        core.run(10_000).unwrap_or_else(|e| panic!("issue_width {issue_width}: {e}"));
        assert_eq!(
            core.reg(A0),
            101,
            "issue_width {issue_width}: core executed a stale cached decode"
        );
    }
}

/// The same SMC program in lockstep: both backends invalidate and
/// re-decode identically, instruction by instruction.
#[test]
fn smc_program_agrees_in_lockstep() {
    use simdsoftcore::cosim::{run_lockstep, LockstepOutcome};
    let prog = backward_patch_program();
    let machine = Machine::paper_default().dram_bytes(fuzz::FUZZ_DRAM_BYTES);
    let mut core = machine.build();
    let mut iss = machine.build_iss();
    core.load(&prog).unwrap();
    iss.load(&prog).unwrap();
    let r = run_lockstep(&mut core, &mut iss, 10_000)
        .unwrap_or_else(|d| panic!("SMC program diverged:\n{d}"));
    assert_eq!(r.outcome, LockstepOutcome::Halted);
    assert_eq!(core.reg(A0), 101);
    assert_eq!(iss.reg(A0), 101);
}
