//! Calibration tests: every figure/table driver must land inside the
//! acceptance bands of DESIGN.md §7 at the scaled default sizes. These
//! are the "shape of the paper" guarantees: who wins, by what factor,
//! where the knees fall.

use simdsoftcore::baseline::{PicoConfig, PicoCore};
use simdsoftcore::core::Core;
use simdsoftcore::workloads::{common, cpubench, memcpy, prefix, sort, stream};

/// Paper: 0.69 GB/s memcpy at VLEN=256/LLC 16 Kbit/150 MHz.
#[test]
fn memcpy_headline_band() {
    let mut core = Core::paper_default();
    let r = memcpy::run(&mut core, 4 * 1024 * 1024, true).unwrap();
    assert!(r.verified);
    let gbps = r.throughput.bytes_per_second() / 1e9;
    assert!((0.5..0.9).contains(&gbps), "memcpy {gbps:.2} GB/s (paper 0.69)");
}

/// Paper Fig. 3 left: monotone improvement with a knee by 8192 bits.
#[test]
fn fig3_left_shape() {
    let mut rates = Vec::new();
    for block_bits in [2048usize, 4096, 8192, 16384] {
        let mut mem = simdsoftcore::mem::MemConfig::paper_default();
        let cap = mem.llc.capacity_bytes();
        mem.llc.block_bits = block_bits;
        mem.llc.sets = cap / (block_bits / 8) / mem.llc.ways;
        let mut core = Core::new(simdsoftcore::core::CoreConfig::paper_default(), mem);
        let r = memcpy::run(&mut core, 2 * 1024 * 1024, true).unwrap();
        rates.push(r.throughput.bytes_per_cycle());
    }
    assert!(rates.windows(2).all(|w| w[1] > w[0]), "monotone: {rates:?}");
    // Knee: the 4096→8192 gain exceeds the 8192→16384 gain (plateau).
    let g1 = rates[2] / rates[1];
    let g2 = rates[3] / rates[2];
    assert!(g1 > g2, "plateau after 8192: gains {g1:.3} then {g2:.3}");
}

/// Paper Fig. 3 right: 1024-bit ≈ 2× the 256-bit rate (in GB/s, despite
/// the lower clock).
#[test]
fn fig3_right_shape() {
    let run = |vlen: usize| {
        let mut core = Core::for_vlen(vlen);
        let r = memcpy::run(&mut core, 2 * 1024 * 1024, true).unwrap();
        r.throughput.bytes_per_second()
    };
    let r256 = run(256);
    let r1024 = run(1024);
    let ratio = r1024 / r256;
    assert!((1.6..2.6).contains(&ratio), "1024/256 ratio {ratio:.2} (paper ≈2.0)");
}

/// Paper Fig. 4: softcore STREAM Copy ≈ 183 MB/s; PicoRV32 ≈ 4.8 MB/s and
/// flat across sizes; gap ≳ 25×.
#[test]
fn fig4_bands() {
    let mut core = Core::paper_default();
    let soft = stream::run(&mut core, stream::Kernel::Copy, 512 * 1024, false).unwrap();
    let soft_mbps = soft.throughput.bytes_per_second() / 1e6;
    assert!((120.0..260.0).contains(&soft_mbps), "softcore Copy {soft_mbps:.1} MB/s");

    let mut pico_rates = Vec::new();
    for n in [2048usize, 8192] {
        let addrs = common::layout_buffers(3, n * 4);
        let prog = stream::build_scalar(stream::Kernel::Copy, addrs[0], addrs[1], addrs[2], n);
        let mut pico = PicoCore::new(PicoConfig::default());
        pico.load(&prog).unwrap();
        pico.host_write(addrs[0], &1i32.to_le_bytes().repeat(n));
        pico.run(1_000_000_000).unwrap();
        pico_rates.push(pico.bytes_per_second(8 * n as u64) / 1e6);
    }
    for r in &pico_rates {
        assert!((2.5..8.0).contains(r), "pico Copy {r:.1} MB/s (paper 4.8)");
    }
    let flatness = pico_rates[1] / pico_rates[0];
    assert!((0.9..1.1).contains(&flatness), "pico rates must be flat: {pico_rates:?}");
    let gap = soft_mbps / pico_rates[0];
    assert!(gap > 25.0, "Copy gap {gap:.0}× (paper 38×)");
}

/// Paper Table 2: DMIPS/MHz 1.47, CoreMark/MHz 2.26 (bands from
/// DESIGN.md).
#[test]
fn table2_bands() {
    let mut core = Core::paper_default();
    let d = cpubench::run_dhrystone_like(&mut core, 150).unwrap();
    assert!(d.verified);
    assert!((1.1..2.0).contains(&d.derived_score), "DMIPS/MHz {:.2}", d.derived_score);
    let mut core = Core::paper_default();
    let c = cpubench::run_coremark_like(&mut core, 50).unwrap();
    assert!(c.verified);
    assert!((1.7..3.0).contains(&c.derived_score), "CoreMark/MHz {:.2}", c.derived_score);
}

/// Paper §4.3.1: 12.1× sort speedup (8–16 accepted at scaled size).
#[test]
fn sort_speedup_band() {
    let n = 32 * 1024;
    let mut c1 = Core::paper_default();
    let q = sort::run_qsort(&mut c1, n).unwrap();
    let mut c2 = Core::paper_default();
    let m = sort::run_vector_mergesort(&mut c2, n).unwrap();
    assert!(q.verified && m.verified);
    let speedup = q.cycles_per_elem / m.cycles_per_elem;
    assert!((8.0..16.0).contains(&speedup), "sort speedup {speedup:.1}× (paper 12.1×)");
}

/// Paper §4.3.2: 4.1× prefix speedup (3–6 accepted).
#[test]
fn prefix_speedup_band() {
    let n = 256 * 1024;
    let mut c1 = Core::paper_default();
    let s = prefix::run(&mut c1, n, false).unwrap();
    let mut c2 = Core::paper_default();
    let v = prefix::run(&mut c2, n, true).unwrap();
    assert!(s.verified && v.verified);
    let speedup = s.cycles_per_elem / v.cycles_per_elem;
    assert!((3.0..6.0).contains(&speedup), "prefix speedup {speedup:.1}× (paper 4.1×)");
}

/// Paper §6: c2_sort does 8 elements in 6 cycles — exact.
#[test]
fn discussion_exact_latency() {
    assert_eq!(simdsoftcore::simd::networks::sort_latency(8), 6);
    assert_eq!(simdsoftcore::simd::networks::sort_latency(4), 3);
}

/// §4.1/4.2 headline ratios: ≥25× STREAM Copy, ≥80× memcpy vs PicoRV32
/// (paper: 38× and 144×).
#[test]
fn picorv32_ratio_bands() {
    // Softcore vector memcpy at STREAM byte convention.
    let mut core = Core::paper_default();
    let v = memcpy::run(&mut core, 2 * 1024 * 1024, true).unwrap();
    let v_mbps = 2.0 * v.throughput.bytes_per_second() / 1e6;

    let n = 8192usize;
    let addrs = common::layout_buffers(3, n * 4);
    let prog = stream::build_scalar(stream::Kernel::Copy, addrs[0], addrs[1], addrs[2], n);
    let mut pico = PicoCore::new(PicoConfig::default());
    pico.load(&prog).unwrap();
    pico.host_write(addrs[0], &1i32.to_le_bytes().repeat(n));
    pico.run(1_000_000_000).unwrap();
    let p_mbps = pico.bytes_per_second(8 * n as u64) / 1e6;

    let ratio = v_mbps / p_mbps;
    assert!(ratio > 80.0, "memcpy ratio {ratio:.0}× (paper 144×)");
}
