//! CLI coverage for `analyze --listing` — the single-file front door to
//! the static analyzer. One planted finding of each severity comes back
//! with its kind tag and the right exit status (errors fail the run,
//! warnings and perf findings do not), and a malformed listing is
//! rejected with a line-numbered parse error rather than a panic.

use std::path::PathBuf;
use std::process::{Command, Output};

fn fixture(name: &str, contents: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("simdsoftcore-cli-{}-{name}.s", std::process::id()));
    std::fs::write(&p, contents).expect("write fixture listing");
    p
}

fn analyze(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_simdsoftcore"))
        .args(args)
        .output()
        .expect("spawn simdsoftcore binary")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// A constant-folded load far outside DRAM is an error-severity finding
/// and must make the listing run exit non-zero.
#[test]
fn planted_error_finding_fails_the_listing() {
    let p = fixture("error", "main:\n    li a0, 0x70000000\n    lw a1, 0(a0)\n    halt\n");
    let out = analyze(&["analyze", "--listing", p.to_str().unwrap()]);
    assert!(!out.status.success(), "error-severity finding must fail the run");
    let text = stdout(&out);
    assert!(
        text.contains("[out-of-dram-access]"),
        "stdout:\n{text}\nstderr:\n{}",
        stderr(&out)
    );
    assert!(stderr(&out).contains("error-severity"), "stderr:\n{}", stderr(&out));
}

/// A dead scalar write is warning severity: reported in the rendering,
/// but the run still exits zero.
#[test]
fn planted_warning_finding_is_reported_but_passes() {
    let p = fixture("warning", "main:\n    li t0, 1\n    li t0, 2\n    sw t0, -4(sp)\n    halt\n");
    let out = analyze(&["analyze", "--listing", p.to_str().unwrap()]);
    assert!(out.status.success(), "warnings must not fail the run: {}", stderr(&out));
    assert!(stdout(&out).contains("[dead-write]"), "stdout:\n{}", stdout(&out));
}

/// Under `--perf` a load feeding its consumer on the next instruction
/// draws a perf-severity load-use-bubble finding; perf findings never
/// fail the run.
#[test]
fn planted_load_use_bubble_surfaces_under_perf() {
    let p = fixture(
        "perf",
        "main:\n    lw t0, -8(sp)\n    addi t1, t0, 1\n    sw t1, -4(sp)\n    halt\n",
    );
    let out = analyze(&["analyze", "--listing", p.to_str().unwrap(), "--perf", "--width", "2"]);
    assert!(out.status.success(), "perf findings must not fail the run: {}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("[load-use-bubble]"), "stdout:\n{text}");
    assert!(text.contains("analyze --perf"), "stdout:\n{text}");
}

/// Listings that do not assemble are rejected with the parse error and
/// its line number on stderr.
#[test]
fn malformed_listing_is_rejected() {
    let p = fixture("malformed", "main:\n    lw a0, 4[sp]\n    halt\n");
    let out = analyze(&["analyze", "--listing", p.to_str().unwrap()]);
    assert!(!out.status.success(), "malformed listing must fail the run");
    let err = stderr(&out);
    assert!(err.contains("error:"), "stderr:\n{err}");
    assert!(err.contains("line 2"), "stderr:\n{err}");
}
