//! Calibration of the non-blocking memory hierarchy: with MSHRs >= 4
//! and the stream prefetcher enabled, streaming workloads must beat the
//! paper's blocking port by a real margin. The narrow-LLC-block point
//! of the `mem-sweep` grid (2048-bit blocks) is where the blocking port
//! exposes the most miss latency — the paper's 16384-bit blocks already
//! amortise much of it by design, so there the bar is "strictly
//! faster", while at 2048 bits the bar is >= 20% fewer cycles.

use simdsoftcore::machine::Machine;
use simdsoftcore::workloads::{lookup, Scenario, Variant, WorkloadReport};

fn run(name: &str, size: usize, configure: impl FnOnce(Machine) -> Machine) -> WorkloadReport {
    let mut w = lookup(name).expect("registered workload");
    let machine = configure(Machine::paper_default());
    machine.run(&mut *w, &Scenario::new(Variant::Vector, size)).expect("runs")
}

fn improvement(name: &str, size: usize, llc_block_bits: usize) -> f64 {
    let blocking = run(name, size, |m| m.llc_block(llc_block_bits));
    let nb = run(name, size, |m| {
        m.llc_block(llc_block_bits).mshrs(8).prefetch_depth(8).dram_channels(2)
    });
    assert_eq!(blocking.verified, Some(true), "{name} blocking run failed verify");
    assert_eq!(nb.verified, Some(true), "{name} non-blocking run failed verify");
    assert!(nb.mem.llc.prefetches > 0, "{name}: prefetcher never fired");
    1.0 - nb.throughput.cycles as f64 / blocking.throughput.cycles as f64
}

#[test]
fn memcpy_improves_at_least_20_percent_at_narrow_blocks() {
    let gain = improvement("memcpy", 2 * 1024 * 1024, 2048);
    assert!(
        gain >= 0.20,
        "memcpy cycle-count improvement {:.1}% below the 20% bar",
        gain * 100.0
    );
}

#[test]
fn stream_copy_improves_at_least_20_percent_at_narrow_blocks() {
    let gain = improvement("stream-copy", 128 * 1024, 2048);
    assert!(
        gain >= 0.20,
        "stream-copy cycle-count improvement {:.1}% below the 20% bar",
        gain * 100.0
    );
}

#[test]
fn streaming_workloads_improve_at_the_paper_block_size_too() {
    for (name, size) in [("memcpy", 2 * 1024 * 1024), ("stream-copy", 128 * 1024)] {
        let gain = improvement(name, size, 16384);
        assert!(gain > 0.0, "{name}: non-blocking must not regress at 16384-bit blocks");
    }
}

/// The bandwidth accounting must show WHERE the blocking cycles went.
/// Scalar memcpy issues independent back-to-back loads (`lw t0; lw t1`),
/// so on the blocking port the second load books bandwidth stalls; the
/// non-blocking run books none (its waits surface as DRAM queue cycles,
/// MSHR waits and RAW stalls instead).
#[test]
fn stall_taxonomy_distinguishes_port_modes() {
    let run_scalar = |configure: fn(Machine) -> Machine| {
        let mut w = lookup("memcpy").expect("registered");
        configure(Machine::paper_default())
            .run(&mut *w, &Scenario::new(Variant::Scalar, 512 * 1024))
            .expect("runs")
    };
    let blocking = run_scalar(|m| m.llc_block(2048));
    assert!(blocking.counters.mem_bw_stall_cycles > 0, "blocking port exposes bandwidth stalls");
    let nb = run_scalar(|m| m.llc_block(2048).mshrs(8).prefetch_depth(8));
    assert_eq!(nb.counters.mem_bw_stall_cycles, 0, "non-blocking port never holds for data");
    assert!(
        nb.throughput.cycles < blocking.throughput.cycles,
        "hit-under-miss + prefetch must speed up scalar memcpy too ({} vs {})",
        nb.throughput.cycles,
        blocking.throughput.cycles
    );
}
