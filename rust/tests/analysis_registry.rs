//! Registry hygiene (DESIGN.md §12): every registered workload program
//! passes the static analyzer with zero error-severity findings — at
//! the default VLEN and a stressed one — and its recovered CFG block
//! boundaries agree with the reference-ISS block lowering. A workload
//! that trips this test has a real structural bug (or the analyzer has
//! a false positive; both block the merge).

use simdsoftcore::analysis::{
    analyze_program, check_block_consistency, recover_cfg, AnalysisConfig,
};
use simdsoftcore::machine::dram_needed;
use simdsoftcore::mem::config::MemConfig;
use simdsoftcore::workloads::{registry, Scenario};

#[test]
fn registry_is_lint_clean_and_block_consistent_across_vlens() {
    let dram_floor = MemConfig::paper_default().dram.size_bytes;
    for vlen in [256usize, 512] {
        for entry in registry() {
            let mut w = entry.make();
            for &variant in w.variants() {
                let sc = Scenario::new(variant, w.default_size()).with_vlen(vlen);
                let prog = w.build(&sc);
                let (bufs, bytes_each) = w.buffers(&sc);
                // Same DRAM sizing rule as Machine::run, so sp-relative
                // and buffer addresses are judged against the capacity
                // the workload actually runs with.
                let cfg = AnalysisConfig {
                    vlen_bits: vlen,
                    dram_bytes: dram_floor.max(dram_needed(bufs, bytes_each)),
                };
                let report = analyze_program(&prog, &cfg);
                assert!(
                    report.is_clean(),
                    "{}/{variant} @vlen {vlen} drew error findings:\n{}",
                    entry.name,
                    report.render(20)
                );
                let (_, graph) = recover_cfg(&prog, &cfg);
                check_block_consistency(&prog, &graph).unwrap_or_else(|e| {
                    panic!("{}/{variant} @vlen {vlen}: {e}", entry.name)
                });
            }
        }
    }
}
