//! Differential memory oracle: every registered workload runs twice —
//! once through the full cache hierarchy, once through the flat
//! "magic memory" reference model (`MemModel::Flat`) — and must produce
//! **identical architectural results**: the same verify() outcome, the
//! same retired-instruction count, and bit-identical final memory
//! images. Only cycle counts may differ. This pins down the invariant
//! that lets timing-model refactors (MSHRs, prefetching, channel
//! counts) proceed freely: caches are a timing concern, never a
//! correctness one.

use simdsoftcore::core::Core;
use simdsoftcore::machine::{dram_needed, Machine};
use simdsoftcore::workloads::{lookup, registry, run_on, Scenario, Variant, WorkloadReport};

/// Run `name`/`variant` at its smoke size on a machine derived from
/// `configure(Machine::paper_default())`, returning the report and the
/// finished (flushed) core for memory-image comparison.
fn run_model(
    name: &str,
    variant: Variant,
    configure: impl FnOnce(Machine) -> Machine,
) -> (WorkloadReport, Core) {
    let mut w = lookup(name).expect("registered workload");
    let sc = Scenario::new(variant, w.smoke_size());
    let (buffers, bytes_each) = w.buffers(&sc);
    // Mirror Machine::run's DRAM sizing so cached and flat runs get
    // byte-identical address spaces.
    let dram = dram_needed(buffers, bytes_each).max(64 * 1024 * 1024);
    let machine = configure(Machine::paper_default().dram_bytes(dram));
    let mut core = machine.build();
    let report = run_on(&mut *w, &mut core, &sc)
        .unwrap_or_else(|e| panic!("{name} {variant} failed to run: {e}"));
    (report, core)
}

fn assert_matches_oracle(name: &str, variant: Variant, configure: fn(Machine) -> Machine) {
    let (r_cached, cached) = run_model(name, variant, configure);
    let (r_flat, flat) = run_model(name, variant, |m| m.magic_memory(true));

    assert_eq!(r_cached.verified, Some(true), "{name} {variant}: cached run failed verify");
    assert_eq!(r_flat.verified, Some(true), "{name} {variant}: flat run failed verify");
    assert_eq!(
        r_cached.throughput.instret, r_flat.throughput.instret,
        "{name} {variant}: instruction count depends on the memory model"
    );

    // run_on already flushed the cached hierarchy; the DRAM images must
    // now be bit-identical.
    let n = cached.mem.dram_size();
    assert_eq!(n, flat.mem.dram_size(), "{name} {variant}: DRAM sizes differ");
    assert!(
        cached.mem.dram_slice(0, n) == flat.mem.dram_slice(0, n),
        "{name} {variant}: final memory images differ between hierarchy and oracle"
    );
}

/// Every (workload, variant) in the registry against the oracle, on the
/// paper-default (blocking) hierarchy.
#[test]
fn every_workload_matches_the_magic_memory_oracle() {
    for entry in registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            assert_matches_oracle(entry.name, variant, |m| m);
        }
    }
}

/// The non-blocking configuration (MSHRs + prefetcher + two DRAM
/// channels) must be architecturally indistinguishable too — the whole
/// point of the differential suite.
#[test]
fn nonblocking_hierarchy_matches_the_oracle() {
    for name in ["memcpy", "stream-copy", "stream-triad", "sort", "prefix", "filter"] {
        let probe = lookup(name).expect("registered");
        for &variant in probe.variants() {
            assert_matches_oracle(name, variant, |m| {
                m.mshrs(8).prefetch_depth(4).dram_channels(2)
            });
        }
    }
}

/// Cycle counts are the one thing that MAY differ — and for a streaming
/// workload the hierarchy must actually be slower than magic memory,
/// otherwise the timing model is vacuous.
#[test]
fn hierarchy_pays_real_cycles_over_the_oracle() {
    let (r_cached, _) = run_model("memcpy", Variant::Vector, |m| m);
    let (r_flat, _) = run_model("memcpy", Variant::Vector, |m| m.magic_memory(true));
    assert!(
        r_cached.throughput.cycles > r_flat.throughput.cycles,
        "cached {} cycles should exceed magic-memory {} cycles",
        r_cached.throughput.cycles,
        r_flat.throughput.cycles
    );
}
