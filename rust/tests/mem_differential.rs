//! Differential memory oracle: every registered workload runs three
//! ways — through the full cache hierarchy, through the flat
//! "magic memory" reference model (`MemModel::Flat`), and on the
//! independent reference ISS (`RefIss`) — and must produce **identical
//! architectural results**: the same verify() outcome, the same
//! retired-instruction count, and bit-identical final memory images.
//! Only cycle counts may differ. This pins down the invariant that lets
//! timing-model refactors (MSHRs, prefetching, channel counts) proceed
//! freely: caches are a timing concern, never a correctness one — and
//! since the ISS column shares no execute logic with the core, a
//! decode/execute bug can no longer hide on both sides of the
//! comparison.

use simdsoftcore::arch::ArchState;
use simdsoftcore::core::Core;
use simdsoftcore::machine::{dram_needed, Machine};
use simdsoftcore::ref_iss::RefIss;
use simdsoftcore::workloads::{
    lookup, registry, run_on, run_on_iss, Scenario, Variant, WorkloadReport,
};

/// Run `name`/`variant` at its smoke size on a machine derived from
/// `configure(Machine::paper_default())`, returning the report and the
/// finished (flushed) core for memory-image comparison.
fn run_model(
    name: &str,
    variant: Variant,
    configure: impl FnOnce(Machine) -> Machine,
) -> (WorkloadReport, Core) {
    let mut w = lookup(name).expect("registered workload");
    let sc = Scenario::new(variant, w.smoke_size());
    let (buffers, bytes_each) = w.buffers(&sc);
    // Mirror Machine::run's DRAM sizing so cached and flat runs get
    // byte-identical address spaces.
    let dram = dram_needed(buffers, bytes_each).max(64 * 1024 * 1024);
    let machine = configure(Machine::paper_default().dram_bytes(dram));
    let mut core = machine.build();
    let report = run_on(&mut *w, &mut core, &sc)
        .unwrap_or_else(|e| panic!("{name} {variant} failed to run: {e}"));
    (report, core)
}

fn assert_matches_oracle(name: &str, variant: Variant, configure: fn(Machine) -> Machine) {
    let (r_cached, cached) = run_model(name, variant, configure);
    let (r_flat, flat) = run_model(name, variant, |m| m.magic_memory(true));

    assert_eq!(r_cached.verified, Some(true), "{name} {variant}: cached run failed verify");
    assert_eq!(r_flat.verified, Some(true), "{name} {variant}: flat run failed verify");
    assert_eq!(
        r_cached.throughput.instret, r_flat.throughput.instret,
        "{name} {variant}: instruction count depends on the memory model"
    );

    // run_on already flushed the cached hierarchy; the DRAM images must
    // now be bit-identical.
    let n = cached.mem.dram_size();
    assert_eq!(n, flat.mem.dram_size(), "{name} {variant}: DRAM sizes differ");
    assert!(
        cached.mem.dram_slice(0, n) == flat.mem.dram_slice(0, n),
        "{name} {variant}: final memory images differ between hierarchy and oracle"
    );
}

/// Like `run_model`, but on the reference ISS backend (the third
/// column of the differential matrix).
fn run_iss(name: &str, variant: Variant) -> (WorkloadReport, RefIss) {
    let mut w = lookup(name).expect("registered workload");
    let sc = Scenario::new(variant, w.smoke_size());
    let (buffers, bytes_each) = w.buffers(&sc);
    let dram = dram_needed(buffers, bytes_each).max(64 * 1024 * 1024);
    let mut iss = Machine::paper_default().dram_bytes(dram).build_iss();
    let report = run_on_iss(&mut *w, &mut iss, &sc)
        .unwrap_or_else(|e| panic!("{name} {variant} failed on the ISS: {e}"));
    (report, iss)
}

/// Every (workload, variant) in the registry against the oracle, on the
/// paper-default (blocking) hierarchy.
#[test]
fn every_workload_matches_the_magic_memory_oracle() {
    for entry in registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            assert_matches_oracle(entry.name, variant, |m| m);
        }
    }
}

/// The ISS column: for all 10 registry workloads (every variant), the
/// independent reference ISS must reach the same verify outcome, the
/// same instret, and a bit-identical final memory image as the timed
/// cached core.
#[test]
fn every_workload_matches_the_reference_iss() {
    for entry in registry() {
        let probe = entry.make();
        for &variant in probe.variants() {
            let name = entry.name;
            let (r_cached, cached) = run_model(name, variant, |m| m);
            let (r_iss, iss) = run_iss(name, variant);

            assert_eq!(r_cached.verified, Some(true), "{name} {variant}: cached verify");
            assert_eq!(r_iss.verified, Some(true), "{name} {variant}: ISS verify");
            assert_eq!(
                r_cached.throughput.instret, r_iss.throughput.instret,
                "{name} {variant}: instruction count differs between core and ISS"
            );

            let n = cached.mem.dram_size();
            assert_eq!(n, iss.mem_size(), "{name} {variant}: memory sizes differ");
            assert!(
                cached.mem.dram_slice(0, n) == iss.mem_slice(0, n),
                "{name} {variant}: final memory images differ between core and ISS"
            );
        }
    }
}

/// The non-blocking configuration (MSHRs + prefetcher + two DRAM
/// channels) must be architecturally indistinguishable too — the whole
/// point of the differential suite.
#[test]
fn nonblocking_hierarchy_matches_the_oracle() {
    for name in ["memcpy", "stream-copy", "stream-triad", "sort", "prefix", "filter"] {
        let probe = lookup(name).expect("registered");
        for &variant in probe.variants() {
            assert_matches_oracle(name, variant, |m| {
                m.mshrs(8).prefetch_depth(4).dram_channels(2)
            });
        }
    }
}

/// Cycle counts are the one thing that MAY differ — and for a streaming
/// workload the hierarchy must actually be slower than magic memory,
/// otherwise the timing model is vacuous.
#[test]
fn hierarchy_pays_real_cycles_over_the_oracle() {
    let (r_cached, _) = run_model("memcpy", Variant::Vector, |m| m);
    let (r_flat, _) = run_model("memcpy", Variant::Vector, |m| m.magic_memory(true));
    assert!(
        r_cached.throughput.cycles > r_flat.throughput.cycles,
        "cached {} cycles should exceed magic-memory {} cycles",
        r_cached.throughput.cycles,
        r_flat.throughput.cycles
    );
}
