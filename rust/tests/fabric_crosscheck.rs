//! Cross-validation of the two fabric backends: the native Rust units
//! (`simd::units`) and the AOT-compiled JAX/Pallas artifacts executed
//! through PJRT (`runtime`). Bit-identical results are required — this is
//! the reproduction's analogue of validating a bitstream against RTL.
//!
//! These tests need the `pjrt` cargo feature (the whole file compiles
//! away without it) and `make artifacts` to have run; they skip (with a
//! message) when the artifact directory is absent so plain `cargo test`
//! stays green in a fresh checkout.

#![cfg(feature = "pjrt")]

use simdsoftcore::asm::Asm;
use simdsoftcore::core::Core;
use simdsoftcore::isa::reg::*;
use simdsoftcore::runtime::{hlo_pool, Fabric};
use simdsoftcore::simd::{CustomUnit, MergeUnit, PrefixUnit, SortUnit, UnitInputs, VecVal};
use simdsoftcore::util::Xoshiro256;
use std::cell::RefCell;
use std::rc::Rc;

fn open_fabric() -> Option<Rc<RefCell<Fabric>>> {
    let dir = Fabric::default_dir();
    if !Fabric::available(&dir) {
        eprintln!("SKIP: fabric artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(RefCell::new(Fabric::open(dir).expect("fabric opens"))))
}

fn inputs(funct3: u8, vrs1: VecVal, vrs2: VecVal) -> UnitInputs {
    UnitInputs { funct3, rs1: 0, rs2: 0, imm: 0, vrs1, vrs2 }
}

#[test]
fn sort_artifact_matches_native_unit() {
    let Some(fabric) = open_fabric() else { return };
    let lanes = fabric.borrow().lanes;
    let mut native = SortUnit::new(lanes);
    let mut rng = Xoshiro256::seeded(101);
    for _ in 0..64 {
        let vals = rng.vec_i32(lanes);
        let nat = native
            .execute(&inputs(0, VecVal::from_i32s(&vals), VecVal::zero(lanes)))
            .unwrap()
            .vrd1
            .unwrap()
            .to_i32s();
        let hlo = fabric.borrow_mut().sort_rows(&vals, 1).unwrap();
        assert_eq!(nat, hlo);
    }
}

#[test]
fn sort_artifact_batch64_matches_std() {
    let Some(fabric) = open_fabric() else { return };
    let lanes = fabric.borrow().lanes;
    let mut rng = Xoshiro256::seeded(7);
    let rows = rng.vec_i32(64 * lanes);
    let out = fabric.borrow_mut().sort_rows(&rows, 64).unwrap();
    for r in 0..64 {
        let mut expect = rows[r * lanes..(r + 1) * lanes].to_vec();
        expect.sort_unstable();
        assert_eq!(&out[r * lanes..(r + 1) * lanes], &expect[..], "row {r}");
    }
}

#[test]
fn merge_artifact_matches_native_unit() {
    let Some(fabric) = open_fabric() else { return };
    let lanes = fabric.borrow().lanes;
    let mut native = MergeUnit::new(lanes);
    let mut rng = Xoshiro256::seeded(202);
    for _ in 0..64 {
        let mut a = rng.vec_i32(lanes);
        let mut b = rng.vec_i32(lanes);
        a.sort_unstable();
        b.sort_unstable();
        let out = native
            .execute(&inputs(0, VecVal::from_i32s(&a), VecVal::from_i32s(&b)))
            .unwrap();
        let (lo, hi) = fabric.borrow_mut().merge_rows(&a, &b, 1).unwrap();
        assert_eq!(out.vrd1.unwrap().to_i32s(), lo);
        assert_eq!(out.vrd2.unwrap().to_i32s(), hi);
    }
}

#[test]
fn prefix_artifact_matches_native_chain() {
    let Some(fabric) = open_fabric() else { return };
    let lanes = fabric.borrow().lanes;
    let mut native = PrefixUnit::new(lanes);
    let mut rng = Xoshiro256::seeded(303);
    let mut hlo_carry = 0i32;
    for _ in 0..32 {
        let vals = rng.vec_i32(lanes);
        let nat = native
            .execute(&inputs(0, VecVal::from_i32s(&vals), VecVal::zero(lanes)))
            .unwrap()
            .vrd1
            .unwrap()
            .to_i32s();
        let (hlo, carry) = fabric.borrow_mut().prefix(&vals, 1, hlo_carry).unwrap();
        hlo_carry = carry;
        assert_eq!(nat, hlo);
    }
    // Carries agree too.
    let nat_carry = native
        .execute(&inputs(2, VecVal::zero(lanes), VecVal::zero(lanes)))
        .unwrap()
        .rd
        .unwrap() as i32;
    assert_eq!(nat_carry, hlo_carry);
}

#[test]
fn sort_block_artifact_sorts() {
    let Some(fabric) = open_fabric() else { return };
    let mut rng = Xoshiro256::seeded(404);
    let vals = rng.vec_i32(4096);
    let mut expect = vals.clone();
    expect.sort_unstable();
    let got = fabric.borrow_mut().sort_block(&vals).unwrap();
    assert_eq!(got, expect);
}

/// The full-system check: a core whose custom slots execute through the
/// compiled artifacts runs the Fig. 6 chunk-sort program and produces
/// (a) the same memory result and (b) the same cycle count as the
/// native-unit core — latencies are structural, datapaths interchangeable.
#[test]
fn core_with_hlo_pool_matches_native_core() {
    let Some(fabric) = open_fabric() else { return };
    let vlen = fabric.borrow().lanes * 32;

    let build = || {
        let mut a = Asm::new();
        let n_chunks = 8;
        let mut rng = Xoshiro256::seeded(55);
        let data: Vec<u32> = (0..n_chunks * 16).map(|_| rng.next_u32()).collect();
        let d = a.words("data", &data);
        a.la(A0, d);
        a.li(A2, 0);
        a.li(A3, (n_chunks * 64) as i64);
        let l = a.here("chunk");
        a.lv(V1, A0, A2);
        a.addi(T0, A2, 32);
        a.lv(V2, A0, T0);
        a.sort8(V1, V1);
        a.sort8(V2, V2);
        a.merge(V1, V2, V1, V2);
        a.sv(V1, A0, A2);
        a.sv(V2, A0, T0);
        a.addi(A2, A2, 64);
        a.bne(A2, A3, l);
        a.prefix_reset();
        a.lv(V3, A0, ZERO);
        a.prefix(V4, V3);
        a.prefix_carry(S0);
        a.halt();
        a.assemble().unwrap()
    };

    let prog = build();

    let mut native = Core::paper_default();
    native.load(&prog).unwrap();
    let nat_run = native.run(1_000_000).unwrap();
    native.mem.flush_all();
    let nat_mem = native.mem.dram_slice(prog.sym("data"), 8 * 64).to_vec();

    let mut hlo = Core::paper_default();
    hlo.pool = hlo_pool(fabric, vlen);
    hlo.load(&prog).unwrap();
    let hlo_run = hlo.run(1_000_000).unwrap();
    hlo.mem.flush_all();
    let hlo_mem = hlo.mem.dram_slice(prog.sym("data"), 8 * 64).to_vec();

    assert_eq!(nat_mem, hlo_mem, "memory results must be bit-identical");
    assert_eq!(nat_run.cycles, hlo_run.cycles, "cycle counts must be identical");
    assert_eq!(native.reg(S0), hlo.reg(S0), "prefix carries must agree");
}
