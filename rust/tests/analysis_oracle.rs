//! The lint-oracle property (DESIGN.md §12): a program the static
//! analyzer passes with **zero error-severity findings** runs to a
//! clean halt on the reference ISS — no fetch fault, no misaligned
//! fetch, no image fault, no watchdog. Checked over 200 fuzzer seeds
//! rotating the generator presets, plus planted-defect listings pinning
//! each major finding kind to the fixture that must trigger it.

use simdsoftcore::analysis::{analyze_program, AnalysisConfig, FindingKind, Report};
use simdsoftcore::asm::{Asm, Program};
use simdsoftcore::fuzz::{self, FUZZ_DRAM_BYTES, OpWeights};
use simdsoftcore::isa::reg::*;
use simdsoftcore::isa::Instr;
use simdsoftcore::ref_iss::RefIss;

fn fuzz_cfg() -> AnalysisConfig {
    AnalysisConfig { vlen_bits: 256, dram_bytes: FUZZ_DRAM_BYTES }
}

fn fixture(f: impl FnOnce(&mut Asm)) -> (Program, Report) {
    let mut a = Asm::new();
    f(&mut a);
    let prog = a.assemble().expect("fixture assembles");
    let report = analyze_program(&prog, &fuzz_cfg());
    (prog, report)
}

#[test]
fn zero_error_programs_run_clean_for_200_seeds() {
    let ops = 200;
    for seed in 0..200u64 {
        let (name, w) = OpWeights::preset_for_seed(seed);
        let prog = fuzz::generate(seed, ops, &w, 256);
        let report = analyze_program(&prog, &fuzz_cfg());
        assert!(
            report.is_clean(),
            "seed {seed} ({name}) drew an error finding:\n{}",
            report.render(20)
        );
        let mut iss = RefIss::new(256, FUZZ_DRAM_BYTES);
        iss.load(&prog).unwrap_or_else(|e| panic!("seed {seed} ({name}): load failed: {e:?}"));
        iss.run(fuzz::max_instrs_for(ops)).unwrap_or_else(|e| {
            panic!(
                "seed {seed} ({name}): zero-error program did not halt cleanly: {e:?}\n{}",
                prog.disassemble()
            )
        });
    }
}

#[test]
fn planted_uninit_vector_read_is_found() {
    let (_, r) = fixture(|a| {
        a.sort8(V2, V1); // v1 never written (only v0 is defined at entry)
        a.halt();
    });
    assert!(r.has_kind(FindingKind::UninitVectorRead), "{}", r.render(20));
    assert!(r.is_clean(), "uninit vector reads are warnings:\n{}", r.render(20));
}

#[test]
fn planted_store_into_text_is_found() {
    let (_, r) = fixture(|a| {
        a.li(T1, 7);
        a.auipc(T0, 0); // t0 = pc, inside the text segment
        a.sw(T1, 0, T0);
        a.halt();
    });
    assert!(r.has_kind(FindingKind::StoreToText), "{}", r.render(20));
    assert!(r.is_clean(), "store-to-text is a warning:\n{}", r.render(20));
}

#[test]
fn planted_branch_past_end_of_text_is_an_error() {
    let (prog, r) = fixture(|a| {
        a.emit(Instr::Jal { rd: ZERO, offset: 4096 }); // far past the last word
        a.halt();
    });
    assert!(r.has_kind(FindingKind::BranchOutOfText), "{}", r.render(20));
    assert!(!r.is_clean());
    // The contrapositive of the oracle: the flagged program really does
    // die on the ISS (the jump lands in zero-filled DRAM, which does
    // not decode).
    let mut iss = RefIss::new(256, FUZZ_DRAM_BYTES);
    iss.load(&prog).expect("fixture image fits");
    assert!(iss.run(10_000).is_err(), "flagged program ran to a clean halt");
}

#[test]
fn planted_misaligned_word_load_is_found() {
    let (_, r) = fixture(|a| {
        a.li(A0, 0x1002);
        a.lw(A1, 0, A0);
        a.halt();
    });
    assert!(r.has_kind(FindingKind::MisalignedAccess), "{}", r.render(20));
    assert!(r.is_clean(), "misaligned data accesses are tolerated at runtime");
}

#[test]
fn planted_out_of_dram_load_is_an_error() {
    let (prog, r) = fixture(|a| {
        a.li(A0, 0x7000_0000);
        a.lw(A1, 0, A0);
        a.halt();
    });
    assert!(r.has_kind(FindingKind::OutOfDramAccess), "{}", r.render(20));
    assert!(!r.is_clean());
    let mut iss = RefIss::new(256, FUZZ_DRAM_BYTES);
    iss.load(&prog).expect("fixture image fits");
    assert!(iss.run(10_000).is_err(), "flagged program ran to a clean halt");
}
