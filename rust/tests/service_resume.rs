//! End-to-end sweep-service tests with the real executor (DESIGN.md
//! §10): crash-resume against a persistent JSONL store, the wedged-point
//! retry bound, and `serve` sessions sharing one store across restarts.
//!
//! The queue-policy unit tests (rust/src/service/queue.rs) use stub
//! executors; everything here simulates for real, so a resumed run is
//! checked for *result* equality — not just bookkeeping — against an
//! uninterrupted one.

use simdsoftcore::coordinator::sweep::{MachinePoint, Parallelism};
use simdsoftcore::service::{
    self, default_exec, GridOptions, Job, JobStatus, Progress, ResultStore, ServeConfig,
};
use simdsoftcore::workloads::Variant;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

fn tmp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("simdsoftcore-{name}-{}.jsonl", std::process::id()));
    p
}

fn memcpy_grid(n: usize) -> Vec<Job> {
    (1..=n)
        .map(|i| Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, i * 4096))
        .collect()
}

fn serial() -> GridOptions {
    GridOptions { parallelism: Parallelism::fixed(1), retries: 0, ..Default::default() }
}

#[test]
fn crash_resume_completes_the_grid_from_the_store() {
    let jobs = memcpy_grid(6);
    let exec = default_exec();

    // Uninterrupted reference run (in-memory store).
    let ref_store = Mutex::new(ResultStore::in_memory());
    let reference: Vec<_> =
        service::run_grid(jobs.clone(), &ref_store, &Progress::new(6), &serial(), &exec, |_| {})
            .into_iter()
            .map(Option::unwrap)
            .collect();

    // "Crash" after 2 executed points, against a persistent store.
    let path = tmp_path("resume");
    let _ = std::fs::remove_file(&path);
    {
        let store = Mutex::new(ResultStore::open(&path).unwrap());
        let crash = GridOptions { stop_after: Some(2), ..serial() };
        let out = service::run_grid(jobs.clone(), &store, &Progress::new(6), &crash, &exec, |_| {});
        assert_eq!(out.iter().filter(|r| r.is_some()).count(), 2, "crash left 4 points unrun");
    } // store dropped: the process is "dead"

    // Restart: reopen the same file. The two completed points must be
    // served from the store; only the missing four execute.
    let store = Mutex::new(ResultStore::open(&path).unwrap());
    assert_eq!(store.lock().unwrap().completed(), 2, "survivors loaded from disk");
    let progress = Progress::new(6);
    let resumed = service::run_grid(jobs, &store, &progress, &serial(), &exec, |_| {});
    let snap = progress.snapshot();
    assert_eq!(snap.cached, 2, "crash survivors are cache hits, not re-simulations");
    assert_eq!(store.lock().unwrap().hits(), 2);
    assert_eq!(snap.completed, 6);
    assert_eq!(snap.failed, 0);

    // The resumed run's results equal the uninterrupted run's, point
    // for point (timing/attempt metadata aside).
    for (a, b) in reference.iter().zip(resumed.iter()) {
        assert_eq!(a.fingerprint(), b.as_ref().unwrap().fingerprint());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn wedged_points_fail_bounded_without_stalling_the_shard() {
    // A pathological instruction budget turns the middle point into a
    // wedged simulation: the watchdog trips every attempt. It must be
    // marked failed after exactly retries + 1 attempts while its
    // neighbours complete normally.
    let healthy = |size: usize| Job::sim(MachinePoint::default(), "memcpy", Variant::Vector, size);
    let wedged = healthy(64 * 1024).with_budget(50);
    let jobs = vec![healthy(4096), wedged.clone(), healthy(8192)];
    let store = Mutex::new(ResultStore::in_memory());
    let opts = GridOptions { retries: 2, ..serial() };
    let progress = Progress::new(3);
    let out = service::run_grid(jobs, &store, &progress, &opts, &default_exec(), |_| {});
    let recs: Vec<_> = out.into_iter().map(Option::unwrap).collect();

    assert_eq!(recs[0].status, JobStatus::Ok);
    assert_eq!(recs[2].status, JobStatus::Ok, "the shard drained past the wedged point");
    assert_eq!(recs[1].status, JobStatus::Failed);
    assert_eq!(recs[1].attempts, 3, "bounded retry: retries + 1 attempts, then give up");
    let err = recs[1].error.as_deref().unwrap();
    assert!(err.contains("watchdog"), "{err}");
    let snap = progress.snapshot();
    assert_eq!((snap.completed, snap.failed, snap.running), (3, 1, 0));

    // Failed records persist for the report but are never servable: a
    // re-submission retries the point instead of caching the failure.
    let p2 = Progress::new(1);
    let out2 = service::run_grid(vec![wedged], &store, &p2, &opts, &default_exec(), |_| {});
    assert_eq!(out2[0].as_ref().unwrap().status, JobStatus::Failed);
    assert_eq!(p2.snapshot().cached, 0, "failures are retried, not served from the store");
}

/// `Write` handle the serve loop can own while the test keeps a view.
#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn serve_session(store_path: &Path, script: &str) -> String {
    let buf = SharedBuf::default();
    let store = ResultStore::open(store_path).unwrap();
    let cfg = ServeConfig { parallelism: Parallelism::fixed(2), ..Default::default() };
    service::serve(std::io::Cursor::new(script.to_string()), buf.clone(), store, &cfg);
    let bytes = buf.0.lock().unwrap().clone();
    String::from_utf8(bytes).unwrap()
}

#[test]
fn serve_sessions_share_one_store_across_restarts() {
    let path = tmp_path("serve-restart");
    let _ = std::fs::remove_file(&path);
    let script = "{\"cmd\":\"submit\",\"sim\":{\"workloads\":[\"memcpy\"],\
                  \"variants\":[\"vector\"],\"size\":16384},\
                  \"sweep\":{\"vlen\":[128,256]}}\n\
                  {\"cmd\":\"shutdown\"}\n";

    // First session simulates both points and persists them.
    let out1 = serve_session(&path, script);
    assert_eq!(out1.matches("\"cached\":false").count(), 2, "{out1}");
    assert_eq!(out1.matches("\"cached\":true").count(), 0);

    // A fresh session on the same store serves the identical submission
    // entirely from cache.
    let out2 = serve_session(&path, script);
    assert_eq!(out2.matches("\"cached\":true").count(), 2, "{out2}");
    assert_eq!(out2.matches("\"cached\":false").count(), 0);
    let _ = std::fs::remove_file(&path);
}
