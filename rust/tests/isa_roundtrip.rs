//! Property tests on the ISA codecs: `decode(encode(i)) == i` for every
//! instruction the generator can produce, and decode never panics on
//! arbitrary words (it may reject them).

use simdsoftcore::isa::instr::{CustomSlot, IPrime, SPrime};
use simdsoftcore::isa::reg::{Reg, VReg};
use simdsoftcore::isa::{decode, encode, Instr};
use simdsoftcore::util::{proptest::check, Xoshiro256};
use simdsoftcore::{prop_assert, prop_assert_eq};

fn rand_reg(rng: &mut Xoshiro256) -> Reg {
    Reg(rng.below(32) as u8)
}

fn rand_vreg(rng: &mut Xoshiro256) -> VReg {
    VReg(rng.below(8) as u8)
}

fn rand_imm12(rng: &mut Xoshiro256) -> i32 {
    rng.range_u32(0, 4095) as i32 - 2048
}

/// Generate an arbitrary well-formed instruction.
fn rand_instr(rng: &mut Xoshiro256) -> Instr {
    use Instr::*;
    let rd = rand_reg(rng);
    let rs1 = rand_reg(rng);
    let rs2 = rand_reg(rng);
    let imm = rand_imm12(rng);
    let sh = rng.below(32) as u8;
    let boff = (rng.range_u32(0, 4094) as i32 - 2048) & !1;
    let joff = (rng.range_u32(0, (1 << 20) - 2) as i32 - (1 << 19)) & !1;
    match rng.below(52) {
        0 => Lui { rd, imm: ((rng.next_u32() & 0xfffff) << 12) as i32 },
        1 => Auipc { rd, imm: ((rng.next_u32() & 0xfffff) << 12) as i32 },
        2 => Jal { rd, offset: joff },
        3 => Jalr { rd, rs1, offset: imm },
        4 => Beq { rs1, rs2, offset: boff },
        5 => Bne { rs1, rs2, offset: boff },
        6 => Blt { rs1, rs2, offset: boff },
        7 => Bge { rs1, rs2, offset: boff },
        8 => Bltu { rs1, rs2, offset: boff },
        9 => Bgeu { rs1, rs2, offset: boff },
        10 => Lb { rd, rs1, offset: imm },
        11 => Lh { rd, rs1, offset: imm },
        12 => Lw { rd, rs1, offset: imm },
        13 => Lbu { rd, rs1, offset: imm },
        14 => Lhu { rd, rs1, offset: imm },
        15 => Sb { rs1, rs2, offset: imm },
        16 => Sh { rs1, rs2, offset: imm },
        17 => Sw { rs1, rs2, offset: imm },
        18 => Addi { rd, rs1, imm },
        19 => Slti { rd, rs1, imm },
        20 => Sltiu { rd, rs1, imm },
        21 => Xori { rd, rs1, imm },
        22 => Ori { rd, rs1, imm },
        23 => Andi { rd, rs1, imm },
        24 => Slli { rd, rs1, shamt: sh },
        25 => Srli { rd, rs1, shamt: sh },
        26 => Srai { rd, rs1, shamt: sh },
        27 => Add { rd, rs1, rs2 },
        28 => Sub { rd, rs1, rs2 },
        29 => Sll { rd, rs1, rs2 },
        30 => Slt { rd, rs1, rs2 },
        31 => Sltu { rd, rs1, rs2 },
        32 => Xor { rd, rs1, rs2 },
        33 => Srl { rd, rs1, rs2 },
        34 => Sra { rd, rs1, rs2 },
        35 => Or { rd, rs1, rs2 },
        36 => And { rd, rs1, rs2 },
        37 => Fence,
        38 => Ecall,
        39 => Ebreak,
        40 => Csrrs { rd, csr: 0xC00 + rng.below(3) as u16, rs1: Reg(0) },
        41 => Mul { rd, rs1, rs2 },
        42 => Mulh { rd, rs1, rs2 },
        43 => Mulhsu { rd, rs1, rs2 },
        44 => Mulhu { rd, rs1, rs2 },
        45 => Div { rd, rs1, rs2 },
        46 => Divu { rd, rs1, rs2 },
        47 => Rem { rd, rs1, rs2 },
        48 => Remu { rd, rs1, rs2 },
        49 | 50 => CustomI {
            slot: CustomSlot::from_index(rng.below(4) as usize).unwrap(),
            funct3: rng.below(4) as u8,
            ops: IPrime {
                vrs1: rand_vreg(rng),
                vrd1: rand_vreg(rng),
                vrs2: rand_vreg(rng),
                vrd2: rand_vreg(rng),
                rs1,
                rd,
            },
        },
        _ => CustomS {
            slot: CustomSlot::from_index(rng.below(4) as usize).unwrap(),
            funct3: 4 + rng.below(4) as u8,
            ops: SPrime {
                vrs1: rand_vreg(rng),
                vrd1: rand_vreg(rng),
                imm: rng.below(2) as u8,
                rs2,
                rs1,
                rd,
            },
        },
    }
}

#[test]
fn encode_decode_roundtrip_property() {
    check("decode(encode(i)) == i", 2000, |rng| {
        let instr = rand_instr(rng);
        let word = match encode(&instr) {
            Ok(w) => w,
            Err(e) => return Err(format!("encode failed for {instr:?}: {e}")),
        };
        let back = match decode(word) {
            Ok(i) => i,
            Err(e) => return Err(format!("decode failed for {instr:?} ({word:#010x}): {e}")),
        };
        prop_assert_eq!(back, instr);
        Ok(())
    });
}

#[test]
fn decode_never_panics_on_arbitrary_words() {
    check("decode total on u32", 5000, |rng| {
        let word = rng.next_u32();
        let _ = decode(word); // may be Err; must not panic
        Ok(())
    });
}

#[test]
fn decoded_instructions_reencode_to_same_word() {
    // For words that decode successfully, encode(decode(w)) must give
    // back w — the codecs are a bijection on the valid subset.
    check("encode(decode(w)) == w", 5000, |rng| {
        let word = rng.next_u32();
        if let Ok(instr) = decode(word) {
            // FENCE is the one documented canonicalisation: the fm/pred/
            // succ hint fields are ignored by this in-order single core,
            // so decode maps every fence variant to the canonical word.
            if matches!(instr, Instr::Fence) {
                return Ok(());
            }
            match encode(&instr) {
                Ok(w2) => prop_assert_eq!(w2, word),
                Err(e) => return Err(format!("re-encode failed for {instr:?}: {e}")),
            }
        }
        Ok(())
    });
}

#[test]
fn disassemble_reassemble_roundtrip() {
    // Display → text assembler → same encoding, for representative
    // instructions (custom forms use the generic cN.iK syntax).
    let mut rng = Xoshiro256::seeded(42);
    let mut checked = 0;
    for _ in 0..500 {
        let instr = rand_instr(&mut rng);
        // Branch/jump displays print raw offsets, which the text
        // assembler takes as labels; skip control flow here.
        if instr.is_branch_or_jump() {
            continue;
        }
        if matches!(instr, Instr::Csrrs { .. } | Instr::Lui { .. } | Instr::Auipc { .. }) {
            continue; // printed in numeric forms outside the asm syntax
        }
        let text = format!("{instr}\necall\n");
        let prog = simdsoftcore::asm::assemble_text(&text)
            .unwrap_or_else(|e| panic!("assembling '{instr}': {e}"));
        let word = encode(&instr).unwrap();
        assert_eq!(prog.text[0], word, "instruction '{instr}'");
        checked += 1;
    }
    assert!(checked > 300, "roundtripped {checked} instructions");
}

/// Exhaustive round-trip of every memory-access form the data-port
/// issue/complete timing split touches — scalar loads/stores at
/// boundary offsets plus the custom I′/S′ vector load/store encodings.
/// The non-blocking rework must not disturb the codecs these paths
/// decode through.
#[test]
fn memory_access_forms_roundtrip_exhaustively() {
    use Instr::*;
    let rd = Reg(10);
    let rs1 = Reg(11);
    let rs2 = Reg(12);
    let mut cases: Vec<Instr> = Vec::new();
    for offset in [-2048i32, -1, 0, 1, 4, 2047] {
        cases.extend([
            Lb { rd, rs1, offset },
            Lh { rd, rs1, offset },
            Lw { rd, rs1, offset },
            Lbu { rd, rs1, offset },
            Lhu { rd, rs1, offset },
            Sb { rs1, rs2, offset },
            Sh { rs1, rs2, offset },
            Sw { rs1, rs2, offset },
        ]);
    }
    // c0.lv / c0.sv: the vector memory ops routed through the same port.
    for funct3 in 0u8..4 {
        cases.push(CustomI {
            slot: CustomSlot::from_index(0).unwrap(),
            funct3,
            ops: IPrime {
                vrs1: VReg(1),
                vrd1: VReg(2),
                vrs2: VReg(3),
                vrd2: VReg(0),
                rs1,
                rd,
            },
        });
    }
    for funct3 in 4u8..8 {
        cases.push(CustomS {
            slot: CustomSlot::from_index(0).unwrap(),
            funct3,
            ops: SPrime { vrs1: VReg(1), vrd1: VReg(2), imm: 1, rs2, rs1, rd },
        });
    }
    for instr in cases {
        let word = encode(&instr).unwrap_or_else(|e| panic!("encode {instr:?}: {e}"));
        let back = decode(word).unwrap_or_else(|e| panic!("decode {instr:?}: {e}"));
        assert_eq!(back, instr, "round-trip of {instr:?}");
    }
}

#[test]
fn prop_assert_macros_compose() {
    check("macros work", 4, |rng| {
        let x = rng.next_u32();
        prop_assert!(x == x, "x must equal itself");
        prop_assert_eq!(x, x);
        Ok(())
    });
}
