//! Golden-trace regression tests: the first ~200 retired instructions
//! of the quickstart program and of a SIMD workload are snapshotted
//! (architecturally only — pc + disassembly, no cycle numbers, see
//! `Trace::render_text`) under `rust/tests/golden/`. Timing refactors
//! are free to move cycles around; silently changing *what executes* is
//! what these tests catch.
//!
//! Regenerate intentionally-changed goldens with `GOLDEN_UPDATE=1 cargo
//! test`. A missing golden file is bootstrapped on first run.
//!
//! As a stored-file-independent check, every trace is also produced a
//! second time on a non-blocking dual-issue machine (8 MSHRs, prefetch,
//! two DRAM channels, issue width 2) and must be byte-identical — the
//! serialisation is timing-invariant by construction.

use simdsoftcore::asm::assemble_text;
use simdsoftcore::core::{Core, Trace};
use simdsoftcore::machine::Machine;
use simdsoftcore::workloads::{lookup, Scenario, Variant};
use std::fs;
use std::path::PathBuf;

const LINES: u64 = 200;

const QUICKSTART: &str = r#"
    .data
    input:  .word 42, -7, 100, 3, -50, 8, 0, 21
    output: .space 32
    .text
    main:
        la   a0, input
        la   a1, output
        c0.lv   v1, a0, zero
        c2.sort v2, v1
        c0.sv   v2, a1, zero
        rdcycle a2
        ecall
"#;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn check_golden(name: &str, actual: &str) {
    let path = golden_dir().join(name);
    let update = std::env::var("GOLDEN_UPDATE").is_ok();
    if update || !path.exists() {
        fs::create_dir_all(golden_dir()).expect("create tests/golden");
        fs::write(&path, actual).expect("write golden file");
        if !update {
            eprintln!("golden {name}: bootstrapped snapshot at {}", path.display());
        }
        return;
    }
    let expect = fs::read_to_string(&path).expect("read golden file");
    assert_eq!(
        actual, expect,
        "golden trace '{name}' diverged — architectural behaviour changed. \
         If intended, regenerate with GOLDEN_UPDATE=1 cargo test"
    );
}

/// Trace the first `LINES` instructions of `prog` on `core`.
fn traced_text(core: &mut Core, prog: &simdsoftcore::asm::Program) -> String {
    core.load(prog).unwrap();
    core.trace = Trace::windowed(0, LINES);
    core.run(1_000_000).expect("traced program runs");
    core.trace.render_text()
}

#[test]
fn quickstart_trace_matches_golden() {
    let prog = assemble_text(QUICKSTART).expect("quickstart assembles");
    let mut core = Core::paper_default();
    let text = traced_text(&mut core, &prog);
    assert!(text.lines().count() >= 7, "quickstart trace suspiciously short:\n{text}");
    // The architectural serialisation prints the generic I'-type form
    // (`c2.i0` is the sort unit's funct3=0 operation).
    assert!(text.contains("c2.i0"), "SIMD instruction missing from trace:\n{text}");

    // Timing-invariance: a non-blocking dual-issue machine retires the
    // identical instruction sequence.
    let mut nb = Machine::paper_default()
        .mshrs(8)
        .prefetch_depth(4)
        .dram_channels(2)
        .issue_width(2)
        .build();
    assert_eq!(traced_text(&mut nb, &prog), text, "trace depends on the timing model");

    check_golden("quickstart.trace", &text);
}

#[test]
fn simd_sort_workload_trace_matches_golden() {
    let run_traced = |machine: Machine| {
        let mut w = lookup("sort").expect("sort registered");
        let sc = Scenario::new(Variant::Vector, w.smoke_size());
        let prog = w.build(&sc);
        let mut core = machine.build();
        core.load(&prog).unwrap();
        w.init(&mut core);
        core.trace = Trace::windowed(0, LINES);
        core.run(simdsoftcore::workloads::common::MAX_INSTRS).expect("sort runs");
        core.trace.render_text()
    };
    let text = run_traced(Machine::paper_default());
    assert!(text.lines().count() >= 50, "sort smoke trace suspiciously short:\n{text}");
    assert!(text.contains("c2.") || text.contains("c1."), "vector sort uses custom units:\n{text}");

    let nb_text = run_traced(
        Machine::paper_default().mshrs(8).prefetch_depth(4).dram_channels(2).issue_width(2),
    );
    assert_eq!(nb_text, text, "trace depends on the timing model");

    check_golden("sort_vector.trace", &text);
}
