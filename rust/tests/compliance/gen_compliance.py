#!/usr/bin/env python3
"""Generate the checked-in rv32ui/rv32um compliance ELFs.

Each output is a little-endian ELF32 ET_EXEC RISC-V binary following the
riscv-tests HTIF convention: the program owns a word-sized `tohost`
symbol, writes 1 on pass or (testnum << 1) | 1 on the first failing
check, then executes `ecall` (the simulator's return-to-host). Binaries
are self-checking, so the simulator needs no golden outputs — only the
final `tohost` word.

The generator is deliberately independent of the Rust code: it encodes
RV32IM from the ISA manual and verifies every emitted binary with its
own mini-interpreter (also written from the manual) before writing it.
Layout mirrors rust/src/loader/write.rs: ehdr + 2 phdrs + text + data +
.symtab/.strtab/.shstrtab + 5 section headers; the data segment has
p_memsz > p_filesz so loading exercises BSS zero-fill.

Run from this directory:  python3 gen_compliance.py
"""

import struct
import sys

M32 = 0xFFFFFFFF
TEXT_BASE = 0x1000
DATA_BASE = 0x100000
TOHOST = DATA_BASE          # word
FROMHOST = DATA_BASE + 4    # word
TDAT = DATA_BASE + 8        # test data words
SCRATCH = DATA_BASE + 0x40  # store-test scratch
BSS_BYTES = 64              # zero-filled tail past p_filesz

X0, X1, GP = 0, 1, 3
T3, T4, T5, T6 = 28, 29, 30, 31


def s32(v):
    v &= M32
    return v - (1 << 32) if v >= 1 << 31 else v


def u32(v):
    return v & M32


# ---------------------------------------------------------------- encodings
def r_type(f7, rs2, rs1, f3, rd):
    return f7 << 25 | rs2 << 20 | rs1 << 15 | f3 << 12 | rd << 7 | 0x33


def i_type(imm, rs1, f3, rd, op):
    return (imm & 0xFFF) << 20 | rs1 << 15 | f3 << 12 | rd << 7 | op


def s_type(imm, rs2, rs1, f3):
    imm &= 0xFFF
    return (imm >> 5) << 25 | rs2 << 20 | rs1 << 15 | f3 << 12 | (imm & 0x1F) << 7 | 0x23


def b_type(off, rs2, rs1, f3):
    off &= 0x1FFF
    return ((off >> 12) & 1) << 31 | ((off >> 5) & 0x3F) << 25 | rs2 << 20 | rs1 << 15 \
        | f3 << 12 | ((off >> 1) & 0xF) << 8 | ((off >> 11) & 1) << 7 | 0x63


def u_type(imm20, rd, op):
    return (imm20 & 0xFFFFF) << 12 | rd << 7 | op


def j_type(off, rd):
    off &= 0x1FFFFF
    return ((off >> 20) & 1) << 31 | ((off >> 1) & 0x3FF) << 21 | ((off >> 11) & 1) << 20 \
        | ((off >> 12) & 0xFF) << 12 | rd << 7 | 0x6F


ECALL = 0x00000073


# ------------------------------------------------------------------ builder
class Asm:
    def __init__(self):
        self.words = []

    @property
    def pc(self):
        return TEXT_BASE + 4 * len(self.words)

    def emit(self, w):
        self.words.append(w & M32)

    def addi(self, rd, rs1, imm):
        self.emit(i_type(imm, rs1, 0, rd, 0x13))

    def li(self, rd, v):
        sv = s32(v)
        if -2048 <= sv <= 2047:
            self.addi(rd, X0, sv)
            return
        val = u32(v)
        lo = val & 0xFFF
        if lo >= 0x800:
            lo -= 0x1000
        hi20 = (u32(val - lo) >> 12) & 0xFFFFF
        self.emit(u_type(hi20, rd, 0x37))
        self.addi(rd, rd, lo)

    def check(self, reg, expected, n):
        """beq reg, expected → continue; else write (n<<1)|1 and halt."""
        self.li(T6, expected)
        self.emit(b_type(16, T6, reg, 0))  # beq reg, t6, +4 instrs
        self.addi(GP, X0, (n << 1) | 1)
        self.emit(s_type(0, GP, X1, 2))    # sw gp, 0(x1)
        self.emit(ECALL)

    def report_pass(self):
        self.addi(GP, X0, 1)
        self.emit(s_type(0, GP, X1, 2))
        self.emit(ECALL)


# ------------------------------------------------------------ expected values
def alu_expected(op, a, b):
    a, b = u32(a), u32(b)
    sa, sb = s32(a), s32(b)
    sh = b & 31
    if op == "add":
        return u32(a + b)
    if op == "sub":
        return u32(a - b)
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "sll":
        return u32(a << sh)
    if op == "srl":
        return a >> sh
    if op == "sra":
        return u32(sa >> sh)
    if op == "slt":
        return 1 if sa < sb else 0
    if op == "sltu":
        return 1 if a < b else 0
    if op == "mul":
        return u32(sa * sb)
    if op == "mulh":
        return u32((sa * sb) >> 32)
    if op == "mulhu":
        return u32((a * b) >> 32)
    if op == "mulhsu":
        return u32((sa * b) >> 32)
    if op == "div":
        if b == 0:
            return M32
        if a == 0x80000000 and b == M32:
            return 0x80000000
        q = abs(sa) // abs(sb)
        return u32(q if (sa < 0) == (sb < 0) else -q)
    if op == "divu":
        return M32 if b == 0 else a // b
    if op == "rem":
        if b == 0:
            return a
        if a == 0x80000000 and b == M32:
            return 0
        r = abs(sa) % abs(sb)
        return u32(r if sa >= 0 else -r)
    if op == "remu":
        return a if b == 0 else a % b
    raise ValueError(op)


R_OPS = {
    "add": (0x00, 0), "sub": (0x20, 0), "sll": (0x00, 1), "slt": (0x00, 2),
    "sltu": (0x00, 3), "xor": (0x00, 4), "srl": (0x00, 5), "sra": (0x20, 5),
    "or": (0x00, 6), "and": (0x00, 7),
    "mul": (0x01, 0), "mulh": (0x01, 1), "mulhsu": (0x01, 2), "mulhu": (0x01, 3),
    "div": (0x01, 4), "divu": (0x01, 5), "rem": (0x01, 6), "remu": (0x01, 7),
}
I_OPS = {"addi": 0, "slti": 2, "sltiu": 3, "xori": 4, "ori": 6, "andi": 7}
B_OPS = {"beq": 0, "bne": 1, "blt": 4, "bge": 5, "bltu": 6, "bgeu": 7}

VALS = [0x00000000, 0x00000001, 0xFFFFFFFF, 0x7FFFFFFF, 0x80000000,
        0x0000FFFF, 0xFFFF8000, 0x12345678, 0xDEADBEEF]
IMMS = [0, 1, -1, 7, 2047, -2048, 0x555]
SHAMTS = [0, 1, 7, 14, 31]
TDAT_WORDS = [0x00FF00FF, 0xFF00FF00, 0x0FF00FF0, 0xF00FF00F, 0xDEADBEEF, 0x80000000]


def branch_taken(op, a, b):
    a, b = u32(a), u32(b)
    sa, sb = s32(a), s32(b)
    return {"beq": a == b, "bne": a != b, "blt": sa < sb, "bge": sa >= sb,
            "bltu": a < b, "bgeu": a >= b}[op]


# --------------------------------------------------------------- test bodies
def gen_test(op):
    a = Asm()
    a.li(X1, TOHOST)
    n = 2  # riscv-tests convention: TESTNUM starts at 2

    if op in R_OPS:
        f7, f3 = R_OPS[op]
        for x in VALS:
            for y in VALS:
                a.li(T3, x)
                a.li(T4, y)
                a.emit(r_type(f7, T4, T3, f3, T5))
                a.check(T5, alu_expected(op, x, y), n)
                n += 1
    elif op in I_OPS:
        base = op[:-1] if op != "sltiu" else "sltu"
        for x in VALS:
            for imm in IMMS:
                a.li(T3, x)
                a.emit(i_type(imm, T3, I_OPS[op], T5, 0x13))
                a.check(T5, alu_expected(base, x, imm), n)
                n += 1
    elif op in ("slli", "srli", "srai"):
        f7 = 0x20 if op == "srai" else 0x00
        f3 = 1 if op == "slli" else 5
        base = {"slli": "sll", "srli": "srl", "srai": "sra"}[op]
        for x in VALS:
            for sh in SHAMTS:
                a.li(T3, x)
                a.emit(i_type((f7 << 5) | sh, T3, f3, T5, 0x13))
                a.check(T5, alu_expected(base, x, sh), n)
                n += 1
    elif op == "lui":
        for imm20 in [0, 1, 0xFFFFF, 0x80000, 0x12345]:
            a.emit(u_type(imm20, T5, 0x37))
            a.check(T5, u32(imm20 << 12), n)
            n += 1
    elif op == "auipc":
        for imm20 in [0, 1, 0x00010]:
            pc = a.pc
            a.emit(u_type(imm20, T5, 0x17))
            a.check(T5, u32(pc + (imm20 << 12)), n)
            n += 1
    elif op in B_OPS:
        for x in VALS:
            for y in VALS:
                a.li(T3, x)
                a.li(T4, y)
                a.addi(T5, X0, 0)
                a.emit(b_type(8, T4, T3, B_OPS[op]))  # skip one instr if taken
                a.addi(T5, T5, 1)
                a.check(T5, 0 if branch_taken(op, x, y) else 1, n)
                n += 1
    elif op == "jal":
        for _ in range(3):
            a.addi(T5, X0, 0)
            link = a.pc + 4
            a.emit(j_type(8, T3))  # jal t3, +2 instrs
            a.addi(T5, T5, 1)      # must be skipped
            a.check(T5, 0, n)
            n += 1
            a.check(T3, link, n)
            n += 1
    elif op == "jalr":
        for off in (0, 4, -4):
            a.addi(T5, X0, 0)
            # li T4 is always 2 instrs here (targets are > 2047).
            target = a.pc + 2 * 4 + 4 + 4
            a.li(T4, target - off)
            link = a.pc + 4
            a.emit(i_type(off, T4, 0, T3, 0x67))  # jalr t3, off(t4)
            a.addi(T5, T5, 1)                     # must be skipped
            a.check(T5, 0, n)
            n += 1
            a.check(T3, link, n)
            n += 1
    elif op in ("lb", "lbu", "lh", "lhu", "lw"):
        f3 = {"lb": 0, "lh": 1, "lw": 2, "lbu": 4, "lhu": 5}[op]
        size = {"lb": 1, "lbu": 1, "lh": 2, "lhu": 2, "lw": 4}[op]
        signed = op in ("lb", "lh")
        data = b"".join(struct.pack("<I", w) for w in TDAT_WORDS)
        for off in range(0, len(data) - size + 1, size):
            raw = int.from_bytes(data[off:off + size], "little")
            if signed and raw >= 1 << (8 * size - 1):
                raw -= 1 << (8 * size)
            a.li(T3, TDAT)
            a.emit(i_type(off, T3, f3, T5, 0x03))
            a.check(T5, u32(raw), n)
            n += 1
        if op == "lw":
            # BSS zero-fill: a word past p_filesz must read back 0.
            a.li(T3, bss_base())
            a.emit(i_type(0, T3, 2, T5, 0x03))
            a.check(T5, 0, n)
            n += 1
    elif op in ("sb", "sh", "sw"):
        f3 = {"sb": 0, "sh": 1, "sw": 2}[op]
        size = 1 << f3
        cases = [(0, 0xDEADBEEF), (size, 0x00C0FFEE), (4, 0x12345678)]
        for off, val in cases:
            word_off = off & ~3
            init = 0xA5A5A5A5
            a.li(T3, SCRATCH)
            a.li(T4, init)
            a.emit(s_type(word_off, T4, T3, 2))       # sw init
            a.li(T4, val)
            a.emit(s_type(off, T4, T3, f3))           # the store under test
            a.emit(i_type(word_off, T3, 2, T5, 0x03))  # lw back the word
            merged = bytearray(struct.pack("<I", init))
            merged[off - word_off:off - word_off + size] = \
                (val & ((1 << (8 * size)) - 1)).to_bytes(size, "little")
            a.check(T5, int.from_bytes(merged, "little"), n)
            n += 1
    else:
        raise ValueError(op)

    a.report_pass()
    return a.words


def data_image():
    img = struct.pack("<II", 0, 0)  # tohost, fromhost
    img += b"".join(struct.pack("<I", w) for w in TDAT_WORDS)
    img += b"\x00" * (SCRATCH - DATA_BASE - len(img))  # pad to scratch
    img += b"\x00" * 8  # scratch words
    return img


def bss_base():
    return DATA_BASE + len(data_image())


# ----------------------------------------------------------------- ELF write
def write_elf(text_words, data, bss, entry, symbols):
    phnum = 2
    phoff = 52
    text_off = phoff + phnum * 32
    text_size = 4 * len(text_words)
    data_off = text_off + text_size

    names = sorted(symbols)
    strtab = b"\x00"
    offs = []
    for nm in names:
        offs.append(len(strtab))
        strtab += nm.encode() + b"\x00"
    symtab = b"\x00" * 16
    for nm, off in zip(names, offs):
        symtab += struct.pack("<IIIBBH", off, symbols[nm], 0, 0x10, 0, 0xFFF1)

    shstrtab = b"\x00.text\x00.symtab\x00.strtab\x00.shstrtab\x00"
    symtab_off = data_off + len(data)
    strtab_off = symtab_off + len(symtab)
    shstrtab_off = strtab_off + len(strtab)
    shoff = shstrtab_off + len(shstrtab)

    ehdr = struct.pack(
        "<4sBBB9xHHIIIIIHHHHHH",
        b"\x7fELF", 1, 1, 1,
        2, 243, 1, entry, phoff, shoff, 0, 52, 32, phnum, 40, 5, 4,
    )
    assert len(ehdr) == 52

    def phdr(off, vaddr, filesz, memsz, flags):
        return struct.pack("<IIIIIIII", 1, off, vaddr, vaddr, filesz, memsz, flags, 4)

    def shdr(name, sh_type, addr, off, size, link, entsize):
        return struct.pack("<IIIIIIIIII", name, sh_type, 0, addr, off, size, link, 0, 4,
                           entsize)

    out = ehdr
    out += phdr(text_off, TEXT_BASE, text_size, text_size, 0x5)        # R+X
    out += phdr(data_off, DATA_BASE, len(data), len(data) + bss, 0x6)  # R+W
    out += b"".join(struct.pack("<I", w) for w in text_words)
    out += data
    out += symtab + strtab + shstrtab
    assert len(out) == shoff
    out += shdr(0, 0, 0, 0, 0, 0, 0)
    out += shdr(1, 1, TEXT_BASE, text_off, text_size, 0, 0)
    out += shdr(7, 2, 0, symtab_off, len(symtab), 3, 16)
    out += shdr(15, 3, 0, strtab_off, len(strtab), 0, 0)
    out += shdr(23, 3, 0, shstrtab_off, len(shstrtab), 0, 0)
    return out


# -------------------------------------------------- independent self-checker
def interpret(text_words, data, bss):
    """Tiny RV32IM interpreter: returns the final tohost word."""
    mem = bytearray(2 * 1024 * 1024)
    for i, w in enumerate(text_words):
        mem[TEXT_BASE + 4 * i:TEXT_BASE + 4 * i + 4] = struct.pack("<I", w)
    mem[DATA_BASE:DATA_BASE + len(data)] = data
    # BSS is already zero in a fresh bytearray.
    regs = [0] * 32
    pc = TEXT_BASE
    for _ in range(1_000_000):
        w = struct.unpack_from("<I", mem, pc)[0]
        op = w & 0x7F
        rd = (w >> 7) & 0x1F
        f3 = (w >> 12) & 7
        rs1 = (w >> 15) & 0x1F
        rs2 = (w >> 20) & 0x1F
        f7 = w >> 25
        imm_i = s32(w) >> 20
        imm_s = ((s32(w) >> 25) << 5) | ((w >> 7) & 0x1F)
        imm_b = (((s32(w) >> 31) << 12) | (((w >> 7) & 1) << 11)
                 | (((w >> 25) & 0x3F) << 5) | (((w >> 8) & 0xF) << 1))
        imm_u = w & 0xFFFFF000
        imm_j = (((s32(w) >> 31) << 20) | (((w >> 12) & 0xFF) << 12)
                 | (((w >> 20) & 1) << 11) | (((w >> 21) & 0x3FF) << 1))
        nxt = pc + 4
        val = None
        if op == 0x37:
            val = imm_u
        elif op == 0x17:
            val = u32(pc + imm_u)
        elif op == 0x6F:
            val = nxt
            nxt = u32(pc + imm_j)
        elif op == 0x67:
            val = nxt
            nxt = u32(regs[rs1] + imm_i) & ~1
        elif op == 0x63:
            names = {0: "beq", 1: "bne", 4: "blt", 5: "bge", 6: "bltu", 7: "bgeu"}
            if branch_taken(names[f3], regs[rs1], regs[rs2]):
                nxt = u32(pc + imm_b)
        elif op == 0x03:
            addr = u32(regs[rs1] + imm_i)
            size = 1 << (f3 & 3)
            raw = int.from_bytes(mem[addr:addr + size], "little")
            if f3 in (0, 1) and raw >= 1 << (8 * size - 1):
                raw -= 1 << (8 * size)
            val = u32(raw)
        elif op == 0x23:
            addr = u32(regs[rs1] + imm_s)
            size = 1 << f3
            mem[addr:addr + size] = (regs[rs2] & ((1 << (8 * size)) - 1)) \
                .to_bytes(size, "little")
        elif op == 0x13:
            name = {0: "add", 2: "slt", 3: "sltu", 4: "xor", 6: "or", 7: "and",
                    1: "sll", 5: "sra" if (w >> 30) & 1 else "srl"}[f3]
            b = (w >> 20) & 0x1F if f3 in (1, 5) else u32(imm_i)
            val = alu_expected(name, regs[rs1], b)
        elif op == 0x33:
            if f7 == 1:
                name = {0: "mul", 1: "mulh", 2: "mulhsu", 3: "mulhu",
                        4: "div", 5: "divu", 6: "rem", 7: "remu"}[f3]
            else:
                name = {0: "sub" if f7 == 0x20 else "add", 1: "sll", 2: "slt",
                        3: "sltu", 4: "xor", 5: "sra" if f7 == 0x20 else "srl",
                        6: "or", 7: "and"}[f3]
            val = alu_expected(name, regs[rs1], regs[rs2])
        elif w == ECALL:
            return struct.unpack_from("<I", mem, TOHOST)[0]
        else:
            raise AssertionError(f"undecodable word {w:#010x} at pc {pc:#x}")
        if val is not None and rd != 0:
            regs[rd] = u32(val)
        pc = nxt
    raise AssertionError("interpreter watchdog: no ecall within 1M steps")


# --------------------------------------------------------------------- main
RV32UI = ["add", "addi", "and", "andi", "auipc", "beq", "bge", "bgeu", "blt",
          "bltu", "bne", "jal", "jalr", "lb", "lbu", "lh", "lhu", "lui", "lw",
          "or", "ori", "sb", "sh", "sll", "slli", "slt", "slti", "sltiu",
          "sltu", "sra", "srai", "srl", "srli", "sub", "sw", "xor", "xori"]
RV32UM = ["div", "divu", "mul", "mulh", "mulhsu", "mulhu", "rem", "remu"]


def main():
    import os
    here = os.path.dirname(os.path.abspath(__file__))
    data = data_image()
    total = 0
    for prefix, ops in (("rv32ui", RV32UI), ("rv32um", RV32UM)):
        for op in ops:
            words = gen_test(op)
            tohost = interpret(words, data, BSS_BYTES)
            if tohost != 1:
                raise AssertionError(
                    f"{prefix}-p-{op}: self-check failed, tohost={tohost:#x} "
                    f"(test {tohost >> 1})")
            elf = write_elf(words, data, BSS_BYTES, TEXT_BASE, {
                "_start": TEXT_BASE, "tohost": TOHOST, "fromhost": FROMHOST,
            })
            name = f"{prefix}-p-{op}.elf"
            with open(os.path.join(here, name), "wb") as f:
                f.write(elf)
            total += 1
            print(f"  {name}: {len(words)} instrs, {len(elf)} bytes, self-check pass")
    print(f"{total} compliance binaries written")


if __name__ == "__main__":
    sys.exit(main())
