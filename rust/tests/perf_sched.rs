//! Static performance model and scheduler acceptance (DESIGN.md §12):
//!
//! 1. The per-block cycle cost model is *cycle-exact* against the timed
//!    core for straight-line code under flat memory, at issue widths
//!    1/2/4 — property-tested over the fuzz generator (branch-free
//!    mixes) and over every basic block of every registry workload.
//! 2. The intra-block scheduler provably preserves semantics (end-state
//!    compare plus lockstep cosim) and buys a measured >= 5% cycle
//!    reduction on at least two registry stream kernels at dual issue.
//!
//! Together these pin the contract the `analyze --perf` / `--schedule`
//! surfaces and the sched-bench CLI rely on.

use std::collections::HashMap;

use simdsoftcore::analysis::{
    recover_cfg, schedule_program, verify_schedule, AnalysisConfig, PerfModel, Terminator,
};
use simdsoftcore::asm::Program;
use simdsoftcore::fuzz::{generate, max_instrs_for, OpWeights, FUZZ_DRAM_BYTES};
use simdsoftcore::isa::{decode, encode, Instr, Reg};
use simdsoftcore::machine::{dram_needed, Machine};
use simdsoftcore::mem::config::MemConfig;
use simdsoftcore::workloads::{common, lookup, registry, Scenario, Variant, Workload};

/// Ops per fuzz case — enough to fill issue groups, stack scoreboard
/// hazards and collide on the custom units, small enough to keep the
/// full 720-case sweep fast.
const FUZZ_OPS: usize = 40;

/// Branch-free generator mixes: with `branch`/`wildjump`/`smc` zeroed
/// the emitted program is straight-line by construction, so the whole
/// text is one model sequence.
fn straight_line_mixes() -> [(&'static str, OpWeights); 2] {
    [
        (
            "scalar",
            OpWeights {
                alu: 6,
                branch: 0,
                muldiv: 2,
                mem: 4,
                vec: 0,
                vecmem: 0,
                wildjump: 0,
                smc: 0,
            },
        ),
        (
            "vector",
            OpWeights {
                alu: 3,
                branch: 0,
                muldiv: 1,
                mem: 1,
                vec: 5,
                vecmem: 4,
                wildjump: 0,
                smc: 0,
            },
        ),
    ]
}

fn decode_all(prog: &Program) -> Vec<(u32, Instr)> {
    prog.text
        .iter()
        .enumerate()
        .map(|(i, &word)| {
            let pc = prog.text_base + (i as u32) * 4;
            let instr =
                decode(word).unwrap_or_else(|_| panic!("{pc:#010x}: {word:08x} does not decode"));
            (pc, instr)
        })
        .collect()
}

/// The tentpole property, half one: on straight-line programs with flat
/// memory the model's [min, max] interval collapses to a point equal to
/// the timed core's cycle counter — for >= 200 fuzz seeds at every
/// supported issue width.
#[test]
fn cost_model_is_cycle_exact_on_straight_line_fuzz_programs() {
    let mut checked = 0usize;
    for (mix, w) in straight_line_mixes() {
        for seed in 0..120u64 {
            let prog = generate(seed, FUZZ_OPS, &w, 256);
            let seq = decode_all(&prog);
            for width in [1usize, 2, 4] {
                let machine = Machine::for_vlen(256)
                    .magic_memory(true)
                    .dram_bytes(FUZZ_DRAM_BYTES)
                    .issue_width(width);
                let cost = PerfModel::flat(*machine.core_config()).sequence_cost(&seq);
                assert!(
                    cost.exact && cost.complete,
                    "{mix} seed {seed} width {width}: model declined to be exact"
                );
                assert_eq!(cost.min_cycles, cost.max_cycles);
                let mut core = machine.build();
                core.load(&prog).expect("fuzz image fits");
                core.run(max_instrs_for(FUZZ_OPS)).unwrap_or_else(|e| {
                    panic!("{mix} seed {seed} width {width}: {e}\n{}", prog.disassemble())
                });
                assert!(core.halted(), "{mix} seed {seed} width {width}: did not halt");
                assert_eq!(
                    core.cycle(),
                    cost.min_cycles,
                    "{mix} seed {seed} width {width}: model/core cycle mismatch\n{}",
                    prog.disassemble()
                );
                checked += 1;
            }
        }
    }
    assert!(checked >= 600, "only {checked} straight-line cases checked");
}

/// The tentpole property, half two: every basic-block body of every
/// registry workload, replayed standalone on a flat-memory core, costs
/// exactly what the model says. Blocks are rebased to pc 0 with an
/// appended `ecall` (pcs anchor findings, never timing) and entered
/// with all scalar registers pointing at a safe DRAM window; blocks
/// whose rebased address arithmetic faults at runtime are skipped, and
/// the test demands a healthy number of validated blocks so the skip
/// path cannot hollow it out.
#[test]
fn cost_model_is_cycle_exact_on_registry_basic_blocks() {
    const SAFE_BASE: u32 = 0x0010_0000;
    const DRAM: usize = 16 * 1024 * 1024;
    let dram_floor = MemConfig::paper_default().dram.size_bytes;
    let mut validated = 0usize;
    let mut mismatches: Vec<String> = Vec::new();
    for entry in registry() {
        let mut w = entry.make();
        let variants = w.variants().to_vec();
        for variant in variants {
            let sc = Scenario::new(variant, w.smoke_size()).with_vlen(256);
            let prog = w.build(&sc);
            let (bufs, bytes_each) = w.buffers(&sc);
            let acfg = AnalysisConfig {
                vlen_bits: 256,
                dram_bytes: dram_floor.max(dram_needed(bufs, bytes_each)),
            };
            let (cache, graph) = recover_cfg(&prog, &acfg);
            for b in graph.blocks.iter().filter(|b| b.reachable && b.ninstr > 0) {
                let mut body: Vec<(u32, Instr)> = graph.instrs(&cache, b).collect();
                // Drop the control-transfer terminator; fall-through
                // blocks end in a plain instruction and keep it.
                if !matches!(b.term, Terminator::FallThrough) {
                    body.pop();
                }
                if body.is_empty() {
                    continue;
                }
                let mut seq: Vec<(u32, Instr)> = body
                    .iter()
                    .enumerate()
                    .map(|(i, &(_, instr))| ((i as u32) * 4, instr))
                    .collect();
                seq.push(((seq.len() as u32) * 4, Instr::Ecall));
                let Ok(words) = seq.iter().map(|(_, i)| encode(i)).collect::<Result<Vec<u32>, _>>()
                else {
                    continue;
                };
                let frag = Program {
                    text_base: 0,
                    text: words,
                    data_base: 0x0080_0000,
                    data: Vec::new(),
                    symbols: HashMap::new(),
                    entry: 0,
                };
                for width in [1usize, 2, 4] {
                    let machine = Machine::for_vlen(256)
                        .magic_memory(true)
                        .dram_bytes(DRAM)
                        .issue_width(width);
                    let cost = PerfModel::flat(*machine.core_config()).sequence_cost(&seq);
                    if !(cost.exact && cost.complete) {
                        continue;
                    }
                    let mut core = machine.build();
                    core.load(&frag).expect("fragment fits");
                    for n in 1..32u8 {
                        core.set_reg(Reg::new(n), SAFE_BASE);
                    }
                    if core.run(seq.len() as u64 + 8).is_err() || !core.halted() {
                        continue;
                    }
                    if core.cycle() == cost.min_cycles {
                        validated += 1;
                    } else {
                        mismatches.push(format!(
                            "{}/{variant} block {:#010x} width {width}: model {} core {}",
                            entry.name,
                            b.pc(graph.base),
                            cost.min_cycles,
                            core.cycle()
                        ));
                    }
                }
            }
        }
    }
    assert!(mismatches.is_empty(), "cost-model mismatches:\n{}", mismatches.join("\n"));
    assert!(validated >= 30, "only {validated} registry blocks validated");
}

/// Build → load → init → run → verify on a fresh core, returning the
/// cycle counter. Mirrors `workloads::run_on` but accepts an explicit
/// program so the scheduled rewrite can be measured under the same
/// workload init/verify harness.
fn run_cycles(machine: &Machine, w: &mut dyn Workload, prog: &Program) -> u64 {
    let mut core = machine.build();
    core.load(prog).expect("program fits in DRAM");
    w.init(&mut core);
    core.run(common::MAX_INSTRS).unwrap_or_else(|e| panic!("run failed: {e}"));
    core.mem.flush_all();
    w.verify(&core).unwrap_or_else(|e| panic!("results failed verification: {e}"));
    core.cycle()
}

/// Scheduler acceptance: on the scalar stream kernels at issue width 2
/// the rewrite is (a) provably equivalent — identical ISS end state and
/// a clean lockstep cosim run — and (b) worth >= 5% of measured cycles
/// on at least two kernels.
#[test]
fn scheduler_cuts_measured_cycles_on_stream_kernels_at_dual_issue() {
    const VLEN: usize = 256;
    const WIDTH: usize = 2;
    const SIZE: usize = 4096;
    let dram_floor = MemConfig::paper_default().dram.size_bytes;
    let mut savings: Vec<(&str, f64)> = Vec::new();
    for name in ["stream-add", "stream-scale", "stream-triad"] {
        let mut w = lookup(name).expect("registered workload");
        let sc = Scenario::new(Variant::Scalar, SIZE).with_vlen(VLEN);
        let prog = w.build(&sc);
        let (bufs, bytes_each) = w.buffers(&sc);
        let dram = dram_floor.max(dram_needed(bufs, bytes_each));
        let acfg = AnalysisConfig { vlen_bits: VLEN, dram_bytes: dram };
        let machine =
            Machine::for_vlen(VLEN).magic_memory(true).dram_bytes(dram).issue_width(WIDTH);
        let outcome = schedule_program(&prog, &acfg, machine.core_config());
        assert!(outcome.changed(), "{name}: scheduler left the program untouched");
        verify_schedule(
            &prog,
            &outcome.program,
            w.init_image(),
            VLEN,
            dram,
            WIDTH,
            common::MAX_INSTRS,
        )
        .unwrap_or_else(|e| panic!("{name}: scheduled program is not equivalent: {e}"));
        let before = run_cycles(&machine, &mut *w, &prog);
        let after = run_cycles(&machine, &mut *w, &outcome.program);
        assert!(after < before, "{name}: scheduled {after} cycles >= original {before}");
        let saved = 100.0 * (before - after) as f64 / before as f64;
        savings.push((name, saved));
    }
    let wins = savings.iter().filter(|(_, s)| *s >= 5.0).count();
    assert!(wins >= 2, "need >= 5% on at least two stream kernels, got {savings:?}");
}
