//! The checked-in rv32ui/rv32um compliance suite (DESIGN.md §13).
//!
//! Every `tests/compliance/*.elf` is a self-checking riscv-tests-style
//! binary (generated and independently verified by `gen_compliance.py`)
//! that reports through the HTIF `tohost` convention. The contract here
//! is differential: each binary must load, run, and report HTIF pass on
//! BOTH the timed core and the reference ISS, and must be clean under
//! the static analyzer — a pass/fail mismatch means the two execution
//! engines disagree about RV32IM architecture.

use simdsoftcore::loader::compliance::{run_elf, suite_files};
use simdsoftcore::loader::ElfWorkload;
use std::path::PathBuf;

fn suite_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/compliance")
}

#[test]
fn the_suite_is_checked_in_and_loadable() {
    let files = suite_files(&suite_dir()).expect("checked-in suite present");
    assert!(
        files.len() >= 40,
        "expected the full rv32ui+rv32um suite, got {} binaries",
        files.len()
    );
    for path in &files {
        let w = ElfWorkload::from_file(path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(!w.program().text.is_empty(), "{}", path.display());
        // Every binary follows the shared HTIF layout.
        assert_eq!(w.tohost_addr(), 0x0010_0000, "{}", path.display());
        assert_eq!(w.program().entry, 0x1000, "{}", path.display());
    }
}

#[test]
fn every_checked_in_binary_passes_on_both_backends() {
    for path in suite_files(&suite_dir()).expect("checked-in suite present") {
        let row = run_elf(&path);
        assert!(
            !row.mismatch(),
            "{}: backend mismatch — core: {} / ISS: {}",
            row.name,
            row.core.detail,
            row.iss.detail
        );
        assert!(row.core.pass, "{}: timed core: {}", row.name, row.core.detail);
        assert!(row.iss.pass, "{}: reference ISS: {}", row.name, row.iss.detail);
        assert!(
            row.core.instret > 0 && row.iss.instret > 0,
            "{}: a passing run must retire instructions",
            row.name
        );
        assert_eq!(
            row.analyzer_errors, 0,
            "{}: static analyzer found error-severity findings",
            row.name
        );
    }
}
