//! Whole-simulator integration tests: programs exercising every layer at
//! once (assembler → core → caches → DRAM → custom units), plus
//! differential properties between the softcore and the PicoRV32 model
//! (same ISA ⇒ same architectural results, different timing).

use simdsoftcore::asm::{assemble_text, Asm};
use simdsoftcore::baseline::{PicoConfig, PicoCore};
use simdsoftcore::core::{Core, CoreConfig};
use simdsoftcore::isa::reg::*;
use simdsoftcore::mem::MemConfig;
use simdsoftcore::util::{proptest::check, Xoshiro256};
use simdsoftcore::{prop_assert, prop_assert_eq};

/// Fibonacci via a recursive function — exercises calls, the stack, and
/// branch patterns.
#[test]
fn recursive_fibonacci() {
    let prog = assemble_text(
        r#"
        main:
            li   a0, 12
            call fib
            ecall
        fib:                      # fib(n): n<2 -> n
            li   t0, 2
            blt  a0, t0, base
            addi sp, sp, -12
            sw   ra, 0(sp)
            sw   s0, 4(sp)
            sw   s1, 8(sp)
            mv   s0, a0
            addi a0, a0, -1
            call fib
            mv   s1, a0           # fib(n-1)
            addi a0, s0, -2
            call fib
            add  a0, a0, s1
            lw   ra, 0(sp)
            lw   s0, 4(sp)
            lw   s1, 8(sp)
            addi sp, sp, 12
            ret
        base:
            ret
    "#,
    )
    .unwrap();
    let mut core = Core::paper_default();
    core.load(&prog).unwrap();
    core.run(10_000_000).unwrap();
    assert_eq!(core.reg(A0), 144, "fib(12)");
}

/// The same scalar program must produce identical architectural results
/// on the softcore and on the PicoRV32 model — they differ only in
/// timing. Random arithmetic programs, differentially tested.
#[test]
fn softcore_and_picorv32_agree_architecturally() {
    check("softcore == picorv32 (scalar)", 24, |rng: &mut Xoshiro256| {
        let mut a = Asm::new();
        let buf = a.buffer("buf", 256, 4);
        a.la(S1, buf);
        // Random straight-line arithmetic over a0..a5 with some memory.
        a.li(A0, rng.next_u32() as i32 as i64);
        a.li(A1, rng.next_u32() as i32 as i64);
        for _ in 0..40 {
            match rng.below(10) {
                0 => a.add(A0, A0, A1),
                1 => a.sub(A1, A1, A0),
                2 => a.xor(A0, A0, A1),
                3 => a.mul(A1, A1, A0),
                4 => a.slli(A0, A0, (rng.below(31) + 1) as u8),
                5 => a.srai(A1, A1, (rng.below(31) + 1) as u8),
                6 => a.sw(A0, (rng.below(32) * 4) as i32, S1),
                7 => a.lw(A1, (rng.below(32) * 4) as i32, S1),
                8 => a.and(A0, A0, A1),
                _ => a.or(A1, A1, A0),
            }
        }
        a.add(A2, A0, A1);
        a.halt();
        let prog = a.assemble().map_err(|e| e.to_string())?;

        let mut soft = Core::paper_default();
        soft.load(&prog).unwrap();
        soft.run(10_000).map_err(|e| e.to_string())?;

        let mut pico = PicoCore::new(PicoConfig::default());
        pico.load(&prog).unwrap();
        pico.run(10_000).map_err(|e| e.to_string())?;

        prop_assert_eq!(soft.reg(A2), pico.reg(A2));
        prop_assert!(
            pico.cycle() > soft.cycle(),
            "pico ({}) must be slower than the softcore ({})",
            pico.cycle(),
            soft.cycle()
        );
        Ok(())
    });
}

/// Vector state must survive arbitrary interleavings of scalar and
/// vector work (scoreboard correctness): the final memory image equals a
/// host-computed model.
#[test]
fn mixed_scalar_vector_program_property() {
    check("mixed scalar/vector == model", 16, |rng: &mut Xoshiro256| {
        let n_vec = 8usize; // vectors of 8 lanes
        let mut a = Asm::new();
        let vals: Vec<u32> = (0..32).map(|_| rng.next_u32()).collect();
        let src = a.words("src", &vals);
        a.dalign(32);
        let dst = a.buffer("dst", 128, 32);
        a.la(S1, src);
        a.la(S2, dst);
        // Sort each of the 4 vectors while doing scalar work in between.
        for i in 0..4 {
            let off = (i * n_vec * 4) as i32;
            a.li(T0, off as i64);
            a.lv(V1, S1, T0);
            a.addi(A0, A0, 13); // scalar noise
            a.sort8(V2, V1);
            a.mul(A0, A0, A0);
            a.sv(V2, S2, T0);
        }
        a.halt();
        let prog = a.assemble().map_err(|e| e.to_string())?;
        let mut core = Core::paper_default();
        core.load(&prog).unwrap();
        core.run(100_000).map_err(|e| e.to_string())?;
        core.mem.flush_all();
        let out = core.mem.dram_slice(prog.sym("dst"), 128).to_vec();
        // Host model: sort each 8-lane group as i32.
        let mut expect = Vec::new();
        for chunk in vals.chunks(8) {
            let mut c: Vec<i32> = chunk.iter().map(|&x| x as i32).collect();
            c.sort_unstable();
            for v in c {
                expect.extend_from_slice(&v.to_le_bytes());
            }
        }
        prop_assert_eq!(out, expect);
        Ok(())
    });
}

/// Cycle counts must be deterministic: same program, same config ⇒ same
/// cycles, across repeated runs and core reloads.
#[test]
fn deterministic_timing() {
    let mut cycles = Vec::new();
    for _ in 0..3 {
        let mut core = Core::paper_default();
        let r = simdsoftcore::workloads::memcpy::run(&mut core, 64 * 1024, true).unwrap();
        cycles.push(r.throughput.cycles);
    }
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
}

/// Timing monotonicity: a strictly larger copy takes strictly more
/// cycles; a slower interconnect never makes it faster.
#[test]
fn timing_monotonicity_properties() {
    let run_with = |bytes: usize, double_rate: bool| {
        let mut mem = MemConfig::paper_default();
        mem.dram.double_rate = double_rate;
        let mut core = Core::new(CoreConfig::paper_default(), mem);
        simdsoftcore::workloads::memcpy::run(&mut core, bytes, true)
            .unwrap()
            .throughput
            .cycles
    };
    let small = run_with(64 * 1024, true);
    let big = run_with(256 * 1024, true);
    assert!(big > small * 3, "4× data ⇒ ~4× cycles ({small} vs {big})");
    let single = run_with(256 * 1024, false);
    assert!(single >= big, "single-rate AXI cannot be faster ({single} vs {big})");
}

/// Text-assembled and builder-assembled versions of the same program
/// produce identical images.
#[test]
fn text_and_builder_assemblers_agree() {
    let text = assemble_text(
        r#"
        main:
            li   a0, 1000
            li   a1, 0
        loop:
            add  a1, a1, a0
            addi a0, a0, -1
            bnez a0, loop
            ecall
    "#,
    )
    .unwrap();

    let mut b = Asm::new();
    b.li(A0, 1000);
    b.li(A1, 0);
    let l = b.here("loop");
    b.add(A1, A1, A0);
    b.addi(A0, A0, -1);
    b.bnez(A0, l);
    b.ecall();
    let built = b.assemble().unwrap();

    assert_eq!(text.text, built.text);

    let mut core = Core::paper_default();
    core.load(&text).unwrap();
    core.run(100_000).unwrap();
    assert_eq!(core.reg(A1), 500500);
}

/// Running with a different VLEN changes vector granularity but not
/// results (the mergesort test covers 128..1024 widths; here we check
/// the cycle ordering: wider vectors ⇒ fewer cycles for memcpy).
#[test]
fn vlen_scaling_reduces_cycles() {
    let mut last = u64::MAX;
    for vlen in [128usize, 256, 512, 1024] {
        let mut core = Core::for_vlen(vlen);
        let r = simdsoftcore::workloads::memcpy::run(&mut core, 256 * 1024, true).unwrap();
        assert!(r.verified);
        assert!(
            r.throughput.cycles < last,
            "vlen {vlen}: {} !< {last}",
            r.throughput.cycles
        );
        last = r.throughput.cycles;
    }
}

/// Self-checking programs can read their own performance counters.
#[test]
fn program_visible_counters_match_host_view() {
    let prog = assemble_text(
        r#"
        main:
            rdcycle   s0
            rdinstret s1
            li  t0, 50
        loop:
            addi t0, t0, -1
            bnez t0, loop
            rdcycle   s2
            rdinstret s3
            sub a0, s2, s0     # elapsed cycles
            sub a1, s3, s1     # retired instructions
            ecall
    "#,
    )
    .unwrap();
    let mut core = Core::paper_default();
    core.load(&prog).unwrap();
    core.run(10_000).unwrap();
    let cycles = core.reg(A0);
    let instrs = core.reg(A1);
    // Between the two rdinstret reads: li + 2×50 loop instructions +
    // the second rdcycle + the second rdinstret itself reading the
    // pre-retire count = 103.
    assert_eq!(instrs, 103);
    assert!(cycles >= instrs, "cycles {cycles} >= instrs {instrs}");
    assert!(cycles < instrs + 40, "loop should run near 1 IPC, got {cycles}");
}
