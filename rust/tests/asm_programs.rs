//! A battery of small verified programs run end-to-end through the text
//! assembler and the softcore — classic kernels exercising instruction
//! semantics the unit tests don't reach in combination (bit tricks,
//! nested loops, tables, mixed signed/unsigned arithmetic).

use simdsoftcore::asm::assemble_text;
use simdsoftcore::core::Core;
use simdsoftcore::isa::reg::*;

fn run(src: &str) -> Core {
    let prog = assemble_text(src).expect("assembles");
    let mut core = Core::paper_default();
    core.load(&prog).unwrap();
    core.run(50_000_000).expect("runs to completion");
    core
}

#[test]
fn popcount_via_shifts() {
    let c = run(r#"
        main:
            li   a0, 0xDEADBEEF
            li   a1, 0          # count
        loop:
            beqz a0, done
            andi t0, a0, 1
            add  a1, a1, t0
            srli a0, a0, 1
            j    loop
        done:
            ecall
    "#);
    assert_eq!(c.reg(A1), 0xDEADBEEFu32.count_ones());
}

#[test]
fn gcd_euclid() {
    let c = run(r#"
        main:
            li a0, 1071
            li a1, 462
        loop:
            beqz a1, done
            remu t0, a0, a1
            mv   a0, a1
            mv   a1, t0
            j    loop
        done:
            ecall
    "#);
    assert_eq!(c.reg(A0), 21);
}

#[test]
fn collatz_steps() {
    let c = run(r#"
        main:
            li a0, 27
            li a1, 0
        loop:
            li   t0, 1
            beq  a0, t0, done
            andi t1, a0, 1
            bnez t1, odd
            srli a0, a0, 1
            j    next
        odd:
            slli t2, a0, 1
            add  a0, a0, t2     # 3n
            addi a0, a0, 1      # 3n + 1
        next:
            addi a1, a1, 1
            j    loop
        done:
            ecall
    "#);
    assert_eq!(c.reg(A1), 111, "Collatz(27) takes 111 steps");
}

#[test]
fn matrix_3x3_multiply() {
    let c = run(r#"
        .data
        a: .word 1, 2, 3, 4, 5, 6, 7, 8, 9
        b: .word 9, 8, 7, 6, 5, 4, 3, 2, 1
        c: .space 36
        .text
        main:
            la s0, a
            la s1, b
            la s2, c
            li s3, 0            # i
        iloop:
            li s4, 0            # j
        jloop:
            li t4, 0            # acc
            li s5, 0            # k
        kloop:
            # a[i*3+k]
            li  t0, 3
            mul t1, s3, t0
            add t1, t1, s5
            slli t1, t1, 2
            add t1, t1, s0
            lw  t2, 0(t1)
            # b[k*3+j]
            mul t1, s5, t0
            add t1, t1, s4
            slli t1, t1, 2
            add t1, t1, s1
            lw  t3, 0(t1)
            mul t2, t2, t3
            add t4, t4, t2
            addi s5, s5, 1
            li  t0, 3
            blt s5, t0, kloop
            # c[i*3+j] = acc
            mul t1, s3, t0
            add t1, t1, s4
            slli t1, t1, 2
            add t1, t1, s2
            sw  t4, 0(t1)
            addi s4, s4, 1
            blt s4, t0, jloop
            addi s3, s3, 1
            blt s3, t0, iloop
            # checksum = c[0] + c[4] + c[8]
            lw a0, 0(s2)
            lw t0, 16(s2)
            add a0, a0, t0
            lw t0, 32(s2)
            add a0, a0, t0
            ecall
    "#);
    // C = A*B for these matrices: diag = 30, 69, 90 → 189.
    assert_eq!(c.reg(A0), 189);
}

#[test]
fn crc32_byte_loop() {
    let c = run(r#"
        .data
        msg: .byte 0x31, 0x32, 0x33, 0x34   # "1234"
        .text
        main:
            la   s0, msg
            li   s1, 4          # length
            li   a0, -1         # crc = 0xFFFFFFFF
            li   s2, 0xEDB88320 # reversed poly
        byte_loop:
            beqz s1, done
            lbu  t0, 0(s0)
            xor  a0, a0, t0
            li   t1, 8
        bit_loop:
            andi t2, a0, 1
            srli a0, a0, 1
            beqz t2, no_xor
            xor  a0, a0, s2
        no_xor:
            addi t1, t1, -1
            bnez t1, bit_loop
            addi s0, s0, 1
            addi s1, s1, -1
            j byte_loop
        done:
            not  a0, a0
            ecall
    "#);
    assert_eq!(c.reg(A0), 0x9be3e0a3, "CRC32 of '1234'");
}

#[test]
fn unsigned_vs_signed_compare_semantics() {
    let c = run(r#"
        main:
            li  t0, -1          # 0xFFFFFFFF
            li  t1, 1
            slt  a0, t0, t1     # signed: -1 < 1 => 1
            sltu a1, t0, t1     # unsigned: 0xFFFFFFFF < 1 => 0
            sltu a2, t1, t0     # 1 < 0xFFFFFFFF => 1
            ecall
    "#);
    assert_eq!((c.reg(A0), c.reg(A1), c.reg(A2)), (1, 0, 1));
}

#[test]
fn jump_table_dispatch() {
    let c = run(r#"
        main:
            li   s0, 2          # select case 2
            la   t0, table
            slli t1, s0, 2
            add  t0, t0, t1
            lw   t1, 0(t0)
            jr   t1
        case0:
            li a0, 100
            ecall
        case1:
            li a0, 200
            ecall
        case2:
            li a0, 300
            ecall
        table:
            .word case0, case1, case2
    "#);
    assert_eq!(c.reg(A0), 300);
}

#[test]
fn fig5_numeric_example_through_text_asm() {
    // The Fig. 5 merge example driven entirely from assembly text.
    let c = run(r#"
        .data
        la_: .word 2, 4, 6, 8, 10, 12, 14, 16
        lb_: .word 1, 3, 5, 7, 9, 11, 13, 15
        .text
        main:
            la a0, la_
            la a1, lb_
            c0.lv v1, a0, zero
            c0.lv v2, a1, zero
            c1.merge v1, v2, v1, v2
            c0.sv v1, a0, zero
            c0.sv v2, a1, zero
            ecall
    "#);
    let mut core = c;
    core.mem.flush_all();
    let lo: Vec<i32> = core
        .mem
        .dram_slice(0x0010_0000, 32)
        .chunks(4)
        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    assert_eq!(lo, vec![1, 2, 3, 4, 5, 6, 7, 8]);
}

#[test]
fn deep_recursion_uses_stack_correctly() {
    // sum(1..=200) via recursion: exercises 200 stack frames.
    let c = run(r#"
        main:
            li a0, 200
            call sum
            ecall
        sum:
            beqz a0, zero_case
            addi sp, sp, -8
            sw   ra, 0(sp)
            sw   a0, 4(sp)
            addi a0, a0, -1
            call sum
            lw   t0, 4(sp)
            add  a0, a0, t0
            lw   ra, 0(sp)
            addi sp, sp, 8
            ret
        zero_case:
            ret
    "#);
    assert_eq!(c.reg(A0), 20100);
}

#[test]
fn vfilt_from_text_assembler_generic_form() {
    // The generic cN.iK syntax reaches instructions without named
    // mnemonics: c1.i3 == vfilt (rd, vrd1, vrd2, rs1, vrs1, vrs2).
    let c = run(r#"
        .data
        vals: .word 5, -3, 10, -7, 2, -1, 8, -9
        .text
        main:
            la a0, vals
            li a1, 0                        # threshold
            c0.lv v1, a0, zero
            c1.i3 a2, v2, v0, a1, v1, v0    # vfilt: count -> a2
            ecall
    "#);
    assert_eq!(c.reg(A2), 4, "four negative lanes");
    assert_eq!(c.vreg(V2).to_i32s()[..4], [-3, -7, -1, -9]);
}
