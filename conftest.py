# Make `pytest python/tests/` work from the repo root: the python tree is
# a build-time-only package rooted at python/.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
