"""L2 correctness: the composed fabric graphs (block sorter, prefix
stream) against their oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import sort_block_ref
from compile.model import merge_rows, prefix_stream, sort_block, sort_rows


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
def test_sort_block_random(n):
    rng = np.random.default_rng(7)
    x = jnp.array(rng.integers(-(2**31), 2**31, size=n, dtype=np.int64).astype(np.int32))
    got = sort_block(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(sort_block_ref(x)))


def test_sort_block_duplicates_and_sorted_input():
    x = jnp.array([5] * 64, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(sort_block(x)), np.asarray(x))
    y = jnp.arange(128, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(sort_block(y)), np.asarray(y))
    z = y[::-1]
    np.testing.assert_array_equal(np.asarray(sort_block(z)), np.asarray(y))


def test_sort_block_other_lane_widths():
    rng = np.random.default_rng(11)
    for lanes in [4, 16]:
        x = jnp.array(rng.integers(-(2**31), 2**31, size=256, dtype=np.int64).astype(np.int32))
        got = sort_block(x, lanes=lanes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(jnp.sort(x)))


def test_prefix_stream_long_chain():
    rng = np.random.default_rng(3)
    carry = jnp.int32(0)
    acc = 0
    for _ in range(4):
        x = jnp.array(rng.integers(-(2**20), 2**20, size=(8, 8), dtype=np.int64).astype(np.int32))
        out, carry_arr = prefix_stream(x, carry)
        carry = carry_arr[0]
        flat = np.asarray(x).reshape(-1)
        expect = []
        for v in flat:
            acc = np.int32(acc + np.int32(v))
            expect.append(acc)
        np.testing.assert_array_equal(np.asarray(out).reshape(-1), np.array(expect, dtype=np.int32))
        assert int(carry) == int(expect[-1])


def test_batched_instruction_views():
    rng = np.random.default_rng(5)
    x = jnp.array(rng.integers(-100, 100, size=(16, 8), dtype=np.int64).astype(np.int32))
    s = sort_rows(x)
    np.testing.assert_array_equal(np.asarray(s), np.sort(np.asarray(x), axis=-1))
    a = jnp.sort(x[:8], axis=-1)
    b = jnp.sort(x[8:], axis=-1)
    lo, hi = merge_rows(a, b)
    both = np.sort(np.concatenate([np.asarray(a), np.asarray(b)], axis=-1), axis=-1)
    np.testing.assert_array_equal(np.asarray(lo), both[:, :8])
    np.testing.assert_array_equal(np.asarray(hi), both[:, 8:])
