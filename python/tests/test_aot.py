"""AOT path: lowering must produce XLA-parseable HLO text with the
expected entry computation, for every artifact the Rust runtime loads."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_smoke():
    text = aot.to_hlo_text(
        lambda x: (model.sort_rows(x),), jnp.zeros((4, 8), dtype=jnp.int32)
    )
    assert "ENTRY" in text
    assert "s32[4,8]" in text


def test_prefix_hlo_has_carry_io():
    text = aot.to_hlo_text(
        lambda x, c: model.prefix_stream(x, c),
        jnp.zeros((2, 8), dtype=jnp.int32),
        jnp.zeros((1,), dtype=jnp.int32),
    )
    assert "ENTRY" in text
    assert "s32[1]" in text  # the carry operand


def test_build_all_writes_artifacts(tmp_path):
    written = aot.build_all(str(tmp_path), lanes=8, batches=[1], block_n=64)
    names = [w[0] for w in written]
    assert names == ["sort8_b1", "merge_b1", "prefix_b1", "sort_block_64"]
    for _, rel, _, size in written:
        assert (tmp_path / rel).exists()
        assert size > 100


def test_lowered_sort_block_still_correct():
    # jit-compile the same function that gets lowered and check numerics —
    # the interpret-mode pallas path must survive jit.
    rng = np.random.default_rng(1)
    x = jnp.array(rng.integers(-1000, 1000, size=128, dtype=np.int64).astype(np.int32))
    got = model.sort_block(x)
    np.testing.assert_array_equal(np.asarray(got), np.sort(np.asarray(x)))
