"""L1 correctness: every Pallas kernel must match its pure-jnp oracle
bit-for-bit, across lane widths (the paper's VLEN sweep), batch sizes and
adversarial int32 inputs. Hypothesis drives the sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

# Hypothesis drives the adversarial sweeps but is not always installed in
# the offline image; without it the deterministic tests still run and the
# property tests skip with a note instead of breaking collection.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):  # type: ignore[misc]
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):  # type: ignore[misc]
        def deco(fn):
            return fn

        return deco

    class _St:  # minimal stand-ins so module-level strategies still build
        @staticmethod
        def integers(**_k):
            return None

        @staticmethod
        def data():
            return None

        @staticmethod
        def lists(*_a, **_k):
            return None

        @staticmethod
        def sampled_from(_v):
            return None

    st = _St()  # type: ignore[assignment]

from compile.kernels.merge import merge
from compile.kernels.networks import (
    bitonic_sort_layers,
    merge_block_layers,
    merge_latency,
    prefix_latency,
    sort_latency,
)
from compile.kernels.prefix_sum import prefix_sum
from compile.kernels.ref import merge_ref, prefix_ref, sort8_ref
from compile.kernels.sort8 import sort8

LANES = [4, 8, 16, 32]  # VLEN 128..1024 (Fig. 3 right)

i32 = st.integers(min_value=-(2**31), max_value=2**31 - 1)


def arr(data, b, lanes):
    vals = data.draw(
        st.lists(i32, min_size=b * lanes, max_size=b * lanes), label=f"x[{b}x{lanes}]"
    )
    return jnp.array(vals, dtype=jnp.int32).reshape(b, lanes)


# ---- structural invariants (match the Rust side and the paper) ---------


def test_network_depths_match_paper():
    assert sort_latency(4) == 3  # Algorithm 1: c1_cycles = 3
    assert sort_latency(8) == 6  # §6: 8 elements in 6 cycles
    assert merge_latency(16) == 5  # Fig. 6 merge stages
    assert prefix_latency(8) == 4  # Fig. 7: log 8 + carry


@pytest.mark.parametrize("n", LANES)
def test_layers_are_parallel(n):
    for net in (bitonic_sort_layers(n), merge_block_layers(n)):
        for layer in net:
            touched = [i for pair in layer for i in pair]
            assert len(touched) == len(set(touched)), "CAS pairs must be disjoint"


# ---- sort kernel --------------------------------------------------------


@pytest.mark.parametrize("lanes", LANES)
@pytest.mark.parametrize("b", [1, 3, 64])
def test_sort_random(lanes, b):
    rng = np.random.default_rng(42)
    x = jnp.array(rng.integers(-(2**31), 2**31, size=(b, lanes), dtype=np.int64).astype(np.int32))
    got = sort8(x, block_b=min(b, 64))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(sort8_ref(x)))


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_sort_hypothesis(data):
    lanes = data.draw(st.sampled_from(LANES))
    b = data.draw(st.sampled_from([1, 2, 4]))
    x = arr(data, b, lanes)
    got = sort8(x, block_b=b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(sort8_ref(x)))


def test_sort_extremes():
    x = jnp.array(
        [[2**31 - 1, -(2**31), 0, -1, 1, 2**31 - 1, -(2**31), 0]], dtype=jnp.int32
    )
    np.testing.assert_array_equal(np.asarray(sort8(x)), np.asarray(sort8_ref(x)))


# ---- merge kernel --------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_merge_hypothesis(data):
    lanes = data.draw(st.sampled_from(LANES))
    b = data.draw(st.sampled_from([1, 2, 4]))
    a = jnp.sort(arr(data, b, lanes), axis=-1)
    x = jnp.sort(arr(data, b, lanes), axis=-1)
    lo, hi = merge(a, x, block_b=b)
    rlo, rhi = merge_ref(a, x)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))


def test_merge_fig5_example():
    # Fig. 5's shape: two sorted octuples merge into a sorted 16-list.
    a = jnp.array([[1, 3, 5, 7, 9, 11, 13, 15]], dtype=jnp.int32)
    b = jnp.array([[0, 2, 4, 6, 8, 10, 12, 14]], dtype=jnp.int32)
    lo, hi = merge(a, b)
    assert np.asarray(lo).tolist() == [[0, 1, 2, 3, 4, 5, 6, 7]]
    assert np.asarray(hi).tolist() == [[8, 9, 10, 11, 12, 13, 14, 15]]


# ---- prefix kernel -------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_prefix_hypothesis(data):
    lanes = data.draw(st.sampled_from(LANES))
    b = data.draw(st.sampled_from([1, 2, 8]))
    x = arr(data, b, lanes)
    carry = jnp.int32(data.draw(i32, label="carry"))
    out, c_out = prefix_sum(x, carry)
    rout, rc = prefix_ref(x, carry)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rout))
    assert int(np.asarray(c_out)[0]) == int(rc)


def test_prefix_carry_chains_batches():
    ones = jnp.ones((2, 8), dtype=jnp.int32)
    out1, c1 = prefix_sum(ones, jnp.int32(0))
    out2, c2 = prefix_sum(ones, c1[0])
    assert np.asarray(out1).reshape(-1).tolist() == list(range(1, 17))
    assert np.asarray(out2).reshape(-1).tolist() == list(range(17, 33))
    assert int(np.asarray(c2)[0]) == 32


def test_prefix_wraps_like_hardware():
    x = jnp.full((1, 8), 2**30, dtype=jnp.int32)
    out, _ = prefix_sum(x, jnp.int32(0))
    ref, _ = prefix_ref(x, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
