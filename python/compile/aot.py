"""AOT path: lower every fabric entry point to HLO **text** under
``artifacts/`` for the Rust runtime (PJRT CPU).

HLO text — NOT ``lowered.compile()`` / serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (lanes = VLEN/32 = 8 by default):

  sort8_b{B}.hlo.txt    (B, L) i32            -> (B, L)
  merge_b{B}.hlo.txt    (B, L), (B, L)        -> (B, L), (B, L)
  prefix_b{B}.hlo.txt   (B, L), (1,) carry    -> (B, L), (1,) carry
  sort_block_{N}.hlo.txt (N,) i32             -> (N,)

plus ``manifest.txt`` (one line per artifact: name, path, shapes) read by
``rust/src/runtime``.

Usage: ``python -m compile.aot --out-dir ../artifacts [--lanes 8]``
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *args) -> str:
    """Lower a jittable function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_all(out_dir: str, lanes: int, batches: list[int], block_n: int) -> list[tuple]:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    for b in batches:
        entries.append(
            (
                f"sort8_b{b}",
                lambda x: (model.sort_rows(x),),
                [spec((b, lanes))],
                f"in=(({b},{lanes}) i32) out=(({b},{lanes}) i32)",
            )
        )
        entries.append(
            (
                f"merge_b{b}",
                lambda a, x: model.merge_rows(a, x),
                [spec((b, lanes)), spec((b, lanes))],
                f"in=(({b},{lanes}) i32, ({b},{lanes}) i32) out=(({b},{lanes}) i32, ({b},{lanes}) i32)",
            )
        )
        entries.append(
            (
                f"prefix_b{b}",
                lambda x, c: model.prefix_stream(x, c),
                [spec((b, lanes)), spec((1,))],
                f"in=(({b},{lanes}) i32, (1,) i32) out=(({b},{lanes}) i32, (1,) i32)",
            )
        )

    entries.append(
        (
            f"sort_block_{block_n}",
            lambda x: (model.sort_block(x, lanes=lanes),),
            [spec((block_n,))],
            f"in=(({block_n},) i32) out=(({block_n},) i32)",
        )
    )

    written = []
    for name, fn, specs, shapes in entries:
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(fn, *specs)
        with open(path, "w") as f:
            f.write(text)
        written.append((name, f"{name}.hlo.txt", shapes, len(text)))
        print(f"  {name:<20} {len(text):>9} chars")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--lanes", type=int, default=8, help="VLEN/32 (Table 1: 8)")
    ap.add_argument("--batch", type=int, nargs="*", default=[1, 64])
    ap.add_argument("--block-n", type=int, default=4096)
    args = ap.parse_args()

    print(f"lowering fabric artifacts (lanes={args.lanes}) to {args.out_dir}")
    written = build_all(args.out_dir, args.lanes, args.batch, args.block_n)

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write(f"# fabric artifacts, lanes={args.lanes}\n")
        for name, rel, shapes, _ in written:
            f.write(f"{name}\t{rel}\t{shapes}\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
