"""L2: JAX compositions of the L1 fabric kernels — the compute graphs the
paper's softcore executes instruction-by-instruction, expressed as whole-
block offloads. These are the functions ``aot.py`` lowers to HLO text for
the Rust runtime.

- ``sort_block``: the §4.3.1 mergesort — chunk-sort with the sorting
  network, then log2 merge passes with the merge block. One artifact
  sorts a whole block; the Rust coordinator uses it both as a golden
  model for the instruction-level simulation and as a "whole-function
  fabric offload" (the §6 discussion of internalising processing).
- ``prefix_stream``: batched c3_prefix with explicit carry chaining.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.merge import merge
from .kernels.prefix_sum import prefix_sum
from .kernels.sort8 import sort8


def sort_block(x: jnp.ndarray, lanes: int = 8) -> jnp.ndarray:
    """Sort a flat int32 vector of power-of-two length >= 2*lanes using
    the paper's algorithm: sort lanes-sized chunks with the c2 network,
    then repeatedly merge runs pairwise with the c1 merge block.

    The merge tree is expressed with static python loops over levels
    (static shapes per level), lax.scan over the data-dependent refill
    steps and vmap over independent run pairs, so the whole function
    lowers to a single HLO module.
    """
    (n,) = x.shape
    assert n % lanes == 0 and (n & (n - 1)) == 0, "n must be a power of two"
    rows = x.reshape(-1, lanes)
    rows = sort8(rows)  # sorted runs of `lanes`

    run = 1  # run length in rows
    n_rows = rows.shape[0]
    while run < n_rows:
        pairs = rows.reshape(-1, 2 * run, lanes)

        def merge_pair(pair, run=run):
            a = pair[:run]  # (run, lanes) sorted run A
            b = pair[run:]  # sorted run B

            def step(state, _):
                ia, ib, carry = state
                # Refill selection (§4.3.1): take the run whose head is
                # smaller; an exhausted run always loses.
                a_head = a[jnp.minimum(ia, run - 1), 0]
                b_head = b[jnp.minimum(ib, run - 1), 0]
                take_a = (ib >= run) | ((ia < run) & (a_head <= b_head))
                nxt = jnp.where(
                    take_a, a[jnp.minimum(ia, run - 1)], b[jnp.minimum(ib, run - 1)]
                )
                ia = ia + jnp.where(take_a, jnp.int32(1), jnp.int32(0))
                ib = ib + jnp.where(take_a, jnp.int32(0), jnp.int32(1))
                lo, hi = merge(carry[None, :], nxt[None, :], block_b=1)
                return (ia, ib, hi[0]), lo[0]

            # Prime the merge register with the first vector of A.
            (_, _, carry), outs = jax.lax.scan(
                step, (jnp.int32(1), jnp.int32(0), a[0]), None, length=2 * run - 1
            )
            return jnp.concatenate([outs, carry[None, :]], axis=0)

        rows = jax.vmap(merge_pair)(pairs).reshape(-1, lanes)
        run *= 2
    return rows.reshape(-1)


def prefix_stream(x: jnp.ndarray, carry: jnp.ndarray):
    """Batched prefix scan with carry-in/out — the L2 view of a stream of
    c3_prefix instructions (Fig. 7)."""
    return prefix_sum(x, carry)


def sort_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Batched c2_sort — one instruction call per row."""
    return sort8(x)


def merge_rows(a: jnp.ndarray, b: jnp.ndarray):
    """Batched c1_merge — one instruction call per row pair."""
    return merge(a, b)
