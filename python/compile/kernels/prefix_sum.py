"""L1 Pallas kernel: the c3_prefix datapath — Hillis-Steele inclusive
scan within each vector plus the carry accumulator chaining batches
(Fig. 7 of the paper).

Hardware adaptation: the paper's stateful Verilog register (the running
total of all previous batches) becomes a carry *operand/result* pair:
the kernel takes the incoming carry, scans the whole batch, and returns
the outgoing carry. Chaining across batches — the hardware's implicit
state — is explicit dataflow at the L2 level, which is also what makes
the AOT artifact a pure function the Rust runtime can replay safely.

Within a batch the cross-row carry is itself a Hillis-Steele scan over
the row totals, so the whole kernel stays data-parallel (log L + log B
min/max-free add layers — VPU-only work).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hillis_steele(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive scan along the last axis via shift-add layers
    (log2(width) steps — the paper's Fig. 7 stages)."""
    width = x.shape[-1]
    shift = 1
    while shift < width:
        shifted = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(shift, 0)])[..., :width]
        x = x + shifted
        shift *= 2
    return x


def _prefix_kernel(x_ref, carry_ref, o_ref, carry_out_ref):
    x = x_ref[...].astype(jnp.int32)  # (B, L)
    carry = carry_ref[0]
    row = _hillis_steele(x)  # per-row inclusive scan
    totals = row[:, -1]  # (B,)
    # Exclusive scan of row totals = carry chain across the batch,
    # computed with the same shift-add network over the batch axis.
    incl = _hillis_steele(totals[None, :])[0]
    excl = incl - totals
    out = row + (excl + carry)[:, None]
    o_ref[...] = out
    carry_out_ref[0] = carry + incl[-1]


@jax.jit
def prefix_sum(x: jnp.ndarray, carry: jnp.ndarray):
    """Inclusive scan of an int32 (B, L) batch with carry-in; returns
    (scanned batch, carry-out). Single grid block: the carry chain makes
    the batch a sequential unit at the instruction level; parallelism is
    inside (lanes) and across independent streams, not across the chain."""
    b, lanes = x.shape
    return pl.pallas_call(
        _prefix_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, lanes), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        interpret=True,
    )(x, carry.reshape(1).astype(jnp.int32))
