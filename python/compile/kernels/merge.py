"""L1 Pallas kernel: the c1_merge datapath — the paper's odd-even merge
block (Fig. 5): merge two sorted L-lane vectors into a sorted 2L-lane
result, low half and high half returned separately (low retires, high
recirculates when merging long lists progressively).

The network is the leading reverse-CAS layer plus a log2(2L)-layer
bitonic merger (depth = log2(2L) + 1, matching the Fig. 6 timing);
each layer is one vectorised min/max + static permutation, as in
``sort8.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .networks import merge_block_layers
from .sort8 import apply_cas_layers


def _merge_kernel(a_ref, b_ref, lo_ref, hi_ref, *, lanes: int):
    x = jnp.concatenate([a_ref[...], b_ref[...]], axis=-1)  # (block_b, 2L)
    x = apply_cas_layers(x, merge_block_layers(2 * lanes))
    lo_ref[...] = x[:, :lanes]
    hi_ref[...] = x[:, lanes:]


@functools.partial(jax.jit, static_argnames=("block_b",))
def merge(a: jnp.ndarray, b: jnp.ndarray, block_b: int = 64):
    """Merge rows of two sorted int32 (B, L) batches; returns (lo, hi)."""
    bsz, lanes = a.shape
    assert a.shape == b.shape
    block = min(block_b, bsz)
    assert bsz % block == 0
    out_shape = jax.ShapeDtypeStruct((bsz, lanes), jnp.int32)
    return pl.pallas_call(
        functools.partial(_merge_kernel, lanes=lanes),
        out_shape=(out_shape, out_shape),
        grid=(bsz // block,),
        in_specs=[
            pl.BlockSpec((block, lanes), lambda i: (i, 0)),
            pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block, lanes), lambda i: (i, 0)),
            pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        ),
        interpret=True,
    )(a, b)
