"""Structural network definitions shared by the Pallas kernels.

Mirrors ``rust/src/simd/networks.rs``: the Verilog templates build
instructions out of compare-and-swap (CAS) layers, and both language
sides derive datapaths *and latencies* from the same layer structure.
The Rust tests cross-check layer counts against the paper's numbers
(6 layers for an 8-input bitonic sorter, etc.); the Python tests
cross-check kernel outputs against pure-jnp oracles.
"""

from __future__ import annotations


def bitonic_sort_layers(n: int) -> list[list[tuple[int, int]]]:
    """Batcher bitonic sorting network: k(k+1)/2 layers for n = 2^k."""
    assert n >= 2 and (n & (n - 1)) == 0, "n must be a power of two"
    layers: list[list[tuple[int, int]]] = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            layer = []
            for i in range(n):
                partner = i ^ j
                if partner > i:
                    if i & k == 0:
                        layer.append((i, partner))
                    else:
                        layer.append((partner, i))
            layers.append(layer)
            j //= 2
        k *= 2
    return layers


def merge_block_layers(two_m: int) -> list[list[tuple[int, int]]]:
    """The paper's merge block (§4.3.1): one leading reverse-CAS layer
    (enabling progressive merging of arbitrarily long lists) followed by
    the log2(2m) bitonic-merge layers. Depth = log2(2m) + 1."""
    assert two_m >= 2 and (two_m & (two_m - 1)) == 0
    m = two_m // 2
    layers = [[(i, two_m - 1 - i) for i in range(m)]]
    j = m
    while j >= 1:
        layer = []
        for i in range(two_m):
            partner = i | j
            if partner != i and partner < two_m:
                layer.append((i, partner))
        layers.append(layer)
        j //= 2
    return layers


def layers_to_perm(n: int, layer: list[tuple[int, int]]):
    """Convert one CAS layer into (partner permutation, takes_min mask).

    Lane ``lo`` of a pair keeps the minimum, lane ``hi`` the maximum;
    unpaired lanes are their own partner (min(x, x) = x).
    """
    partner = list(range(n))
    takes_min = [True] * n
    for lo, hi in layer:
        partner[lo] = hi
        partner[hi] = lo
        takes_min[lo] = True
        takes_min[hi] = False
    return partner, takes_min


def sort_latency(n: int) -> int:
    return len(bitonic_sort_layers(n))


def merge_latency(two_m: int) -> int:
    return len(merge_block_layers(two_m))


def prefix_latency(n: int) -> int:
    """log2(n) Hillis-Steele layers + 1 carry layer (Fig. 7)."""
    assert (n & (n - 1)) == 0
    return n.bit_length() - 1 + 1
