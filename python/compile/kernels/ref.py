"""Pure-jnp correctness oracles for the Pallas fabric kernels.

These are the *specification*: every Pallas kernel must match its oracle
bit-for-bit over int32 inputs (including extremes), enforced by
``python/tests/test_kernels.py`` with hypothesis sweeps, and the Rust
native units must match the AOT-compiled kernels (cross-validated in
``rust/tests/fabric_crosscheck.rs``).
"""

from __future__ import annotations

import jax.numpy as jnp


def sort8_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Sort each row of (B, L) int32 ascending — the c2_sort semantics."""
    return jnp.sort(x, axis=-1)


def merge_ref(a: jnp.ndarray, b: jnp.ndarray):
    """Odd-even merge semantics (c1_merge, Fig. 5): rows of `a` and `b`
    are sorted; return (low half, high half) of the merged rows."""
    both = jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)
    lanes = a.shape[-1]
    return both[..., :lanes], both[..., lanes:]


def prefix_ref(x: jnp.ndarray, carry: jnp.ndarray):
    """c3_prefix semantics over a batch (Fig. 7): inclusive scan of the
    flattened (B, L) input plus the incoming carry; returns the scanned
    batch and the outgoing carry (carry + total). Wrapping int32."""
    b, lanes = x.shape
    flat = x.reshape(-1)
    scan = jnp.cumsum(flat, dtype=jnp.int32) + carry.astype(jnp.int32)
    return scan.reshape(b, lanes), scan[-1]


def memcpy_ref(x: jnp.ndarray) -> jnp.ndarray:
    """c0_lv/c0_sv round trip — the identity over vectors."""
    return x


def sort_block_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Full block sorter (the L2 composition): sort a flat int32 vector."""
    return jnp.sort(x)
