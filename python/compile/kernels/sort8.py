"""L1 Pallas kernel: the c2_sort datapath — a Batcher bitonic sorting
network over the lanes of each vector register in a batch.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the Verilog
template's CAS modules (Algorithm 1 of the paper) become one vectorised
``minimum``/``maximum`` pair per network layer over a statically permuted
view of the lane axis — the FPGA's wire permutation is a static gather,
one VPU step per layer. The batch dimension streams through VMEM via the
BlockSpec grid, the Pallas analogue of the instruction pipeline accepting
one call per cycle (II = 1).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is exactly what
the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .networks import bitonic_sort_layers


def apply_cas_layers(x: jnp.ndarray, layers) -> jnp.ndarray:
    """Apply CAS layers column-wise: each (lo, hi) pair becomes one
    min/max pair over batch columns — the literal translation of the
    Verilog CAS module wiring (static, no captured array constants, which
    pallas_call forbids inside kernels)."""
    lanes = x.shape[-1]
    cols = [x[:, i] for i in range(lanes)]
    for layer in layers:
        out = list(cols)
        for lo, hi in layer:
            a, b = cols[lo], cols[hi]
            out[lo] = jnp.minimum(a, b)
            out[hi] = jnp.maximum(a, b)
        cols = out
    return jnp.stack(cols, axis=1)


def _sort_kernel(x_ref, o_ref, *, lanes: int):
    x = x_ref[...]  # (block_b, lanes) int32, VMEM-resident
    o_ref[...] = apply_cas_layers(x, bitonic_sort_layers(lanes))


@functools.partial(jax.jit, static_argnames=("block_b",))
def sort8(x: jnp.ndarray, block_b: int = 64) -> jnp.ndarray:
    """Sort each row of an int32 (B, L) batch. B must divide by block_b
    or be smaller (single block)."""
    b, lanes = x.shape
    block = min(block_b, b)
    assert b % block == 0, f"batch {b} not divisible by block {block}"
    return pl.pallas_call(
        functools.partial(_sort_kernel, lanes=lanes),
        out_shape=jax.ShapeDtypeStruct((b, lanes), jnp.int32),
        grid=(b // block,),
        in_specs=[pl.BlockSpec((block, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        interpret=True,
    )(x)
