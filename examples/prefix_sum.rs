//! §4.3.2 end to end: prefix sum with the stateful c3_prefix instruction
//! (Fig. 7): Hillis-Steele network + carry accumulator, chaining
//! arbitrarily long inputs through a pipelined, non-blocking scan.
//!
//! ```sh
//! cargo run --release --example prefix_sum [-- --n 1048576]
//! ```

use simdsoftcore::asm::Asm;
use simdsoftcore::core::Core;
use simdsoftcore::isa::reg::*;
use simdsoftcore::workloads::prefix;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args
        .iter()
        .position(|a| a == "--n")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(256 * 1024);

    println!("prefix sum over {n} elements\n");
    let mut core = Core::paper_default();
    let s = prefix::run(&mut core, n, false)?;
    println!(
        "serial loop   : {:>12} cycles ({:.2} cycles/elem, verified: {})",
        s.throughput.cycles, s.cycles_per_elem, s.verified
    );
    let mut core = Core::paper_default();
    let v = prefix::run(&mut core, n, true)?;
    println!(
        "c3_prefix     : {:>12} cycles ({:.2} cycles/elem, verified: {})",
        v.throughput.cycles, v.cycles_per_elem, v.verified
    );
    println!(
        "speedup       : {:.1}×   (paper: 4.1×)\n",
        s.cycles_per_elem / v.cycles_per_elem
    );

    // Demonstrate the carry accumulator explicitly: scan two batches,
    // read the carry, reset, scan again.
    let mut a = Asm::new();
    let d = a.words("d", &[1u32; 16]);
    a.la(A0, d);
    a.prefix_reset();
    a.lv(V1, A0, ZERO);
    a.prefix(V2, V1);
    a.li(T0, 32);
    a.lv(V3, A0, T0);
    a.prefix(V4, V3);
    a.prefix_carry(A1); // carry after 16 ones = 16
    a.prefix_reset();
    a.prefix_carry(A2); // after reset = 0
    a.halt();
    let p = a.assemble()?;
    let mut core = Core::paper_default();
    core.load(&p);
    core.run(1000)?;
    println!("carry demo: batch1 scan = {}", core.vreg(V2));
    println!("            batch2 scan = {} (continues from carry)", core.vreg(V4));
    println!("            carry read  = {} ; after reset = {}", core.reg(A1), core.reg(A2));
    assert_eq!(core.reg(A1), 16);
    assert_eq!(core.reg(A2), 0);
    println!("OK");
    Ok(())
}
