//! §4.3.1 end to end: sorting with custom SIMD instructions.
//!
//! Builds both sorting implementations from the paper — `qsort()` (libc
//! model, indirect comparator calls) and the vector mergesort
//! (`c2_sort` chunks + `c1_merge` passes) — runs them on the simulated
//! softcore, verifies both, and prints the speedup next to the paper's
//! 12.1× claim. Also renders the Fig. 6 pipeline trace for the
//! chunk-sort loop.
//!
//! ```sh
//! cargo run --release --example sorting_acceleration [-- --n 262144]
//! ```

use simdsoftcore::coordinator::experiments;
use simdsoftcore::core::{Core, Trace};
use simdsoftcore::workloads::sort;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args
        .iter()
        .position(|a| a == "--n")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(64 * 1024);
    if !n.is_power_of_two() || n < 32 {
        return Err("--n must be a power of two >= 32".into());
    }

    println!("sorting {n} random 32-bit integers on the simulated softcore\n");

    let mut core = Core::paper_default();
    let q = sort::run_qsort(&mut core, n)?;
    println!(
        "qsort() model        : {:>12} cycles  ({:.1} cycles/elem, verified: {})",
        q.throughput.cycles, q.cycles_per_elem, q.verified
    );

    let mut core = Core::paper_default();
    let m = sort::run_vector_mergesort(&mut core, n)?;
    println!(
        "vector mergesort     : {:>12} cycles  ({:.1} cycles/elem, verified: {})",
        m.throughput.cycles, m.cycles_per_elem, m.verified
    );
    println!(
        "speedup              : {:.1}×   (paper: 12.1× at 16M elements)\n",
        q.cycles_per_elem / m.cycles_per_elem
    );
    println!("memory system after mergesort: {}", core.mem.stats().report());

    // Fig. 6: trace the steady-state chunk loop.
    println!("\n{}", experiments::fig6());

    // Bonus: watch the pipelining — two back-to-back sorts through a
    // traced micro-run.
    let mut a = simdsoftcore::asm::Asm::new();
    use simdsoftcore::isa::reg::*;
    let d = a.words("d", &(0..16u32).rev().collect::<Vec<_>>());
    a.la(A0, d);
    a.lv(V1, A0, ZERO);
    a.addi(T0, ZERO, 32);
    a.lv(V2, A0, T0);
    a.sort8(V3, V1);
    a.sort8(V4, V2);
    a.merge(V3, V4, V3, V4);
    a.halt();
    let p = a.assemble()?;
    let mut core = Core::paper_default();
    core.trace = Trace::full();
    core.load(&p);
    core.run(100)?;
    println!("micro-trace (note overlapping sort pipelines):");
    println!("{}", core.trace.render_pipeline());
    Ok(())
}
