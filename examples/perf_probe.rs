//! perf-stat / profiling probe: one deterministic workload mix used by
//! the §Perf optimisation process (EXPERIMENTS.md) — run it under
//! `perf record` to profile the simulator hot path.
use simdsoftcore::core::Core;

fn main() {
    let mut core = Core::paper_default();
    let r = simdsoftcore::workloads::memcpy::run(&mut core, 16 * 1024 * 1024, true).unwrap();
    assert!(r.verified);
    let mut core = Core::paper_default();
    let r2 = simdsoftcore::workloads::sort::run_qsort(&mut core, 64 * 1024).unwrap();
    assert!(r2.verified);
    let mut core = Core::paper_default();
    let r3 = simdsoftcore::workloads::sort::run_vector_mergesort(&mut core, 256 * 1024).unwrap();
    assert!(r3.verified);
    println!(
        "{} {} {}",
        r.throughput.instret, r2.throughput.instret, r3.throughput.instret
    );
}
