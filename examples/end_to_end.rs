//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! 1. **L1/L2 (build time)**: `make artifacts` lowered the Pallas sorting
//!    /merge/prefix datapaths and the composed block sorter to HLO text.
//! 2. **Runtime**: this binary loads those artifacts through PJRT (the
//!    "bitstreams" of the reconfigurable instruction region).
//! 3. **L3**: the cycle-level softcore runs the paper's §4.3.1 sorting
//!    workload twice — once with native datapaths, once with every
//!    custom instruction executing through the compiled artifacts — and
//!    the results must be bit-identical with identical cycle counts.
//! 4. Headline metric: the paper's sort speedup (12.1×) and memcpy rate
//!    (0.69 GB/s) measured on the composed system.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use simdsoftcore::coordinator::{experiments, Scale};
use simdsoftcore::core::Core;
use simdsoftcore::runtime::{hlo_pool, Fabric};
use simdsoftcore::util::Xoshiro256;
use simdsoftcore::workloads::sort;
use std::cell::RefCell;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();

    // ---- 1+2: load the fabric ------------------------------------------
    let dir = Fabric::default_dir();
    anyhow::ensure!(
        Fabric::available(&dir),
        "fabric artifacts missing — run `make artifacts` first"
    );
    let fabric = Rc::new(RefCell::new(Fabric::open(&dir)?));
    println!("[1] fabric loaded from {:?}: {:?}", dir, fabric.borrow().names());
    let vlen = fabric.borrow().lanes * 32;

    // ---- 3: the same sort program on both fabric backends ---------------
    let n = 4096usize;
    println!("\n[2] sorting {n} elements on the simulated softcore, twice:");

    let mut native = Core::paper_default();
    let nat = sort::run_vector_mergesort(&mut native, n)?;
    println!(
        "    native units : {:>9} cycles, verified: {}",
        nat.throughput.cycles, nat.verified
    );

    let mut hlo = Core::paper_default();
    hlo.pool = hlo_pool(fabric.clone(), vlen);
    let hl = sort::run_vector_mergesort(&mut hlo, n)?;
    println!(
        "    HLO fabric   : {:>9} cycles, verified: {}  (every c1/c2/c3 call ran through PJRT)",
        hl.throughput.cycles, hl.verified
    );
    anyhow::ensure!(nat.verified && hl.verified, "sort results must verify");
    anyhow::ensure!(
        nat.throughput.cycles == hl.throughput.cycles,
        "cycle counts must be identical across fabric backends"
    );
    println!("    ✓ bit-identical results, identical cycle counts");

    // Whole-function offload: the L2 composed sorter artifact.
    let mut rng = Xoshiro256::seeded(99);
    let vals = rng.vec_i32(4096);
    let offloaded = fabric.borrow_mut().sort_block(&vals)?;
    let mut expect = vals.clone();
    expect.sort_unstable();
    anyhow::ensure!(offloaded == expect, "sort_block artifact must sort");
    println!("    ✓ L2 sort_block artifact sorts 4096 elements (whole-function offload)");

    // ---- 4: headline metrics --------------------------------------------
    println!("\n[3] headline metrics (scaled inputs; pass --full to benches for paper sizes):");
    let scale = Scale { full: false };
    print!("{}", experiments::memcpy_headline(scale).render());
    print!("{}", experiments::sec43_sort(scale).render());

    println!("\nend-to-end driver completed in {:.2?} (host)", t0.elapsed());
    Ok(())
}
