//! Quickstart: assemble a program that uses a custom SIMD instruction,
//! run it on the simulated softcore, inspect results and cycle counts.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use simdsoftcore::asm::assemble_text;
use simdsoftcore::core::Core;
use simdsoftcore::isa::reg::*;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A program in the text-assembler syntax: load 8 integers into a
    // vector register, sort them with the c2 sorting-network instruction
    // (one instruction, 6 cycles — §6 of the paper), store them back.
    let prog = assemble_text(
        r#"
        .data
        input:  .word 42, -7, 100, 3, -50, 8, 0, 21
        output: .space 32
        .text
        main:
            la   a0, input
            la   a1, output
            c0.lv   v1, a0, zero     # load vector
            c2.sort v2, v1           # bitonic sort, 6-cycle pipeline
            c0.sv   v2, a1, zero     # store vector
            rdcycle a2               # read cycle counter
            ecall
    "#,
    )?;

    println!("disassembly:\n{}", prog.disassemble());

    let mut core = Core::paper_default(); // Table 1 configuration
    core.load(&prog);
    let run = core.run(10_000)?;

    core.mem.flush_all();
    let out: Vec<i32> = core
        .mem
        .dram_slice(prog.sym("output"), 32)
        .chunks(4)
        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
        .collect();

    println!("sorted output: {out:?}");
    println!(
        "executed {} instructions in {} cycles (IPC {:.2}) — {:.1} ns at 150 MHz",
        run.instret,
        run.cycles,
        run.ipc(),
        core.cfg.cycles_to_seconds(run.cycles) * 1e9
    );
    println!("cycle counter read by the program (a2): {}", core.reg(A2));
    println!("memory system: {}", core.mem.stats().report());

    assert_eq!(out, vec![-50, -7, 0, 3, 8, 21, 42, 100]);
    println!("OK");
    Ok(())
}
