//! §4.1 design-space exploration (Fig. 3): sweep the LLC block size and
//! the vector register width for memcpy throughput, then explore a
//! *custom* point — the framework's purpose is exactly this kind of
//! experiment ("a means to experiment with advanced SIMD instructions").
//!
//! ```sh
//! cargo run --release --example design_space_exploration [-- --full]
//! ```

use simdsoftcore::coordinator::{experiments, Scale};
use simdsoftcore::core::{Core, CoreConfig};
use simdsoftcore::mem::MemConfig;
use simdsoftcore::workloads::memcpy;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let scale = Scale { full };

    print!("{}", experiments::fig3_left(scale).render());
    println!();
    print!("{}", experiments::fig3_right(scale).render());
    println!();

    // A point the paper did not publish: what does single-rate AXI
    // (without the §3.1.4 double-rate optimisation) cost at the selected
    // configuration?
    let bytes = if full { 64 * 1024 * 1024 } else { 8 * 1024 * 1024 };
    let mut single = MemConfig::paper_default();
    single.dram.size_bytes = 192 * 1024 * 1024;
    single.dram.double_rate = false;
    let mut core = Core::new(CoreConfig::paper_default(), single);
    let slow = memcpy::run(&mut core, bytes, true)?;

    let mut dbl = MemConfig::paper_default();
    dbl.dram.size_bytes = 192 * 1024 * 1024;
    let mut core = Core::new(CoreConfig::paper_default(), dbl);
    let fast = memcpy::run(&mut core, bytes, true)?;

    println!("== ablation: §3.1.4 double-rate interconnect ==");
    println!(
        "single rate: {:.2} GB/s   double rate: {:.2} GB/s   gain: {:.2}×",
        slow.throughput.bytes_per_second() / 1e9,
        fast.throughput.bytes_per_second() / 1e9,
        fast.throughput.bytes_per_second() / slow.throughput.bytes_per_second()
    );

    // And the NRU-vs-worst-case ablation: shrink LLC associativity to 1
    // (direct-mapped LLC) to show why the replacement/organisation
    // choices matter for streaming.
    let mut dm = MemConfig::paper_default();
    dm.dram.size_bytes = 192 * 1024 * 1024;
    let cap = dm.llc.capacity_bytes();
    dm.llc.ways = 1;
    dm.llc.sets = cap / dm.llc.block_bytes();
    let mut core = Core::new(CoreConfig::paper_default(), dm);
    let dmr = memcpy::run(&mut core, bytes, true)?;
    println!(
        "direct-mapped LLC: {:.2} GB/s ({:.2}× vs 4-way NRU)",
        dmr.throughput.bytes_per_second() / 1e9,
        dmr.throughput.bytes_per_second() / fast.throughput.bytes_per_second()
    );
    Ok(())
}
