//! §4.1 design-space exploration (Fig. 3): sweep the LLC block size and
//! the vector register width for memcpy throughput, then explore a
//! *custom* point — the framework's purpose is exactly this kind of
//! experiment ("a means to experiment with advanced SIMD instructions").
//!
//! ```sh
//! cargo run --release --example design_space_exploration [-- --full]
//! ```

use simdsoftcore::coordinator::{experiments, Scale};
use simdsoftcore::machine::Machine;
use simdsoftcore::workloads::memcpy;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let full = std::env::args().any(|a| a == "--full");
    let scale = Scale { full };

    print!("{}", experiments::fig3_left(scale).render());
    println!();
    print!("{}", experiments::fig3_right(scale).render());
    println!();

    // A point the paper did not publish: what does single-rate AXI
    // (without the §3.1.4 double-rate optimisation) cost at the selected
    // configuration?
    let bytes = if full { 64 * 1024 * 1024 } else { 8 * 1024 * 1024 };
    let dram = 192 * 1024 * 1024;
    let mut core = Machine::paper_default().dram_bytes(dram).double_rate(false).build();
    let slow = memcpy::run(&mut core, bytes, true)?;

    let mut core = Machine::paper_default().dram_bytes(dram).build();
    let fast = memcpy::run(&mut core, bytes, true)?;

    println!("== ablation: §3.1.4 double-rate interconnect ==");
    println!(
        "single rate: {:.2} GB/s   double rate: {:.2} GB/s   gain: {:.2}×",
        slow.throughput.bytes_per_second() / 1e9,
        fast.throughput.bytes_per_second() / 1e9,
        fast.throughput.bytes_per_second() / slow.throughput.bytes_per_second()
    );

    // And the NRU-vs-worst-case ablation: shrink LLC associativity to 1
    // (direct-mapped LLC) to show why the replacement/organisation
    // choices matter for streaming.
    let mut core = Machine::paper_default().dram_bytes(dram).llc_ways(1).build();
    let dmr = memcpy::run(&mut core, bytes, true)?;
    println!(
        "direct-mapped LLC: {:.2} GB/s ({:.2}× vs 4-way NRU)",
        dmr.throughput.bytes_per_second() / 1e9,
        dmr.throughput.bytes_per_second() / fast.throughput.bytes_per_second()
    );
    Ok(())
}
